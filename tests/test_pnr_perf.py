"""Multi-core PnR subsystem: vectorized SA kernel equivalence, the
process-backed ``compile_batch`` backend, the disk compile-cache tier, and
the env-var config plumbing."""

import json
import pickle

import numpy as np
import pytest

from repro.core import (ALL_APPS, CascadeCompiler, CompileCache, DiskCache,
                        PassConfig, cache_dir, worker_count)
from repro.core.cache import DISK_SCHEMA_VERSION
from repro.core.interconnect import Fabric
from repro.core.netlist import extract_netlist
from repro.core.pipelining import compute_pipelining
from repro.core.place import (PlaceParams, _net_cost, _net_cost_batch, _Nets,
                              place)


# ---------------------------------------------------------------------------
# vectorized SA kernel
# ---------------------------------------------------------------------------


def _random_netlist_arrays(rng, n_nodes=40, n_nets=25, max_deg=6):
    """Random positions + random padded net-terminal matrices."""
    pos = rng.integers(-1, 32, size=(n_nodes, 2)).astype(np.int64)
    term_mat = np.zeros((n_nets, max_deg), dtype=np.int64)
    term_count = np.zeros(n_nets, dtype=np.int64)
    nets = []
    for ni in range(n_nets):
        deg = int(rng.integers(2, max_deg + 1))
        term = rng.choice(n_nodes, size=deg, replace=False).astype(np.int64)
        nets.append(term)
        term_mat[ni, :deg] = term
        term_mat[ni, deg:] = term[0]
        term_count[ni] = deg
    return pos, nets, term_mat, term_count


def test_net_cost_batch_matches_scalar_bitwise_on_random_netlists():
    """Eq. 1 vectorized over padded matrices == the scalar reference,
    bit for bit, across random geometries and (gamma, alpha) corners."""
    rng = np.random.default_rng(7)
    for trial in range(5):
        pos, nets, term_mat, term_count = _random_netlist_arrays(rng)
        for gamma, alpha in ((0.3, 1.0), (0.3, 1.6), (0.0, 2.5), (1.7, 1.3)):
            batch = _net_cost_batch(pos, term_mat, term_count, gamma, alpha)
            scalar = [_net_cost(pos, t, gamma, alpha) for t in nets]
            for ni in range(len(nets)):
                assert batch[ni] == scalar[ni]   # bitwise, not approx


def test_padded_terminal_matrix_preserves_net_structure():
    nl = extract_netlist(ALL_APPS["unsharp"].build(1))
    nets = _Nets(nl)
    for ni, term in enumerate(nets.nets):
        row = nets.term_mat[ni]
        assert nets.term_count[ni] == len(term)
        assert set(row.tolist()) == set(term.tolist())   # padding repeats
        assert (row[len(term):] == term[0]).all()


def test_vectorized_place_matches_scalar_place_bitwise():
    """Same seed, both kernels: identical RNG stream, bit-identical costs,
    therefore identical accept/reject decisions and final placement."""
    g = ALL_APPS["harris"].build(1)
    compute_pipelining(g, 4)
    nl = extract_netlist(g)
    fab = Fabric()
    placements, stats = {}, {}
    for mode in (True, False):
        st = {}
        placements[mode] = place(
            nl, fab, PlaceParams(alpha=1.6, seed=3, moves_per_node=40,
                                 vectorized=mode), stats=st)
        stats[mode] = st
    assert placements[True] == placements[False]
    assert stats[True]["best_cost"] == stats[False]["best_cost"]   # bitwise
    assert stats[True]["moves_accepted"] == stats[False]["moves_accepted"]
    assert stats[True]["vectorized"] and not stats[False]["vectorized"]


def test_place_debug_resync_passes_and_counts():
    """The per-temperature-step resync runs (and its assertions hold) on a
    real app under the debug flag."""
    nl = extract_netlist(ALL_APPS["vecadd"].build(1))
    st = {}
    place(nl, Fabric(), PlaceParams(seed=0, moves_per_node=20, debug=True),
          stats=st)
    assert st["resyncs"] > 0
    assert st["moves_evaluated"] >= st["moves_accepted"] > 0


def test_place_stats_surface_in_pass_stats():
    r = CascadeCompiler(cache=CompileCache()).compile(
        ALL_APPS["unsharp"], PassConfig.full(place_moves=20))
    ps = r.pass_stats["pnr"]["place"]
    assert ps["vectorized"] and ps["place_seconds"] > 0
    assert ps["nodes"] > 0 and ps["nets"] > 0


# ---------------------------------------------------------------------------
# process-backed compile_batch
# ---------------------------------------------------------------------------


def _summaries(results):
    return [json.dumps(r.summary()) for r in results]


def test_process_backend_byte_identical_to_serial():
    jobs = [(ALL_APPS[a], PassConfig.full(place_moves=20))
            for a in ("unsharp", "vecadd")]
    serial = [CascadeCompiler(cache=CompileCache()).compile(
        app, cfg, use_cache=False) for app, cfg in jobs]
    c = CascadeCompiler(cache=CompileCache())
    batch = c.compile_batch(jobs, backend="process", max_workers=2)
    assert _summaries(batch) == _summaries(serial)
    assert c.last_batch["backend"] == "process"
    assert c.last_batch["compiled"] == 2 and c.last_batch["cache_hits"] == 0
    # and the parent merged the worker results into its cache
    again = c.compile_batch(jobs, backend="process")
    assert all(r.cache_hit for r in again)
    assert c.last_batch["compiled"] == 0 and c.last_batch["cache_hits"] == 2


def test_auto_backend_picks_process_only_for_multi_miss_batches():
    c = CascadeCompiler(cache=CompileCache())
    app = ALL_APPS["vecadd"]
    c.compile_batch([(app, PassConfig.full(place_moves=20))])
    assert c.last_batch["backend"] == "thread"     # single miss: no fork
    jobs = [(app, PassConfig.full(place_moves=20, seed=s)) for s in (1, 2)]
    c.compile_batch(jobs)
    assert c.last_batch["backend"] == "process"
    c.compile_batch(jobs)                          # warm: all hits
    assert c.last_batch["cache_hits"] == 2 and c.last_batch["compiled"] == 0


def test_process_backend_unpicklable_job_falls_back_inline():
    app = ALL_APPS["vecadd"]
    # a closure builder cannot cross the process boundary
    from dataclasses import replace
    orig = ALL_APPS["elemmul"].builder
    weird = replace(ALL_APPS["elemmul"],
                    builder=lambda c, g, w: orig(c, g, w),
                    name="elemmul_closure")
    with pytest.raises(Exception):
        pickle.dumps(weird)
    c = CascadeCompiler(cache=CompileCache())
    out = c.compile_batch([(app, PassConfig.full(place_moves=20)),
                           (weird, PassConfig.full(place_moves=20))],
                          backend="process", max_workers=2)
    assert [r.summary()["app"] for r in out] == ["vecadd", "elemmul_closure"]
    assert c.last_batch["inline_fallback"] == 1


def test_lmmap_specs_are_picklable_for_process_jobs():
    from repro.configs import ARCHS
    from repro.core.lmmap import lower_block
    for cfg in list(ARCHS.values())[:3]:
        spec = lower_block(cfg)
        clone = pickle.loads(pickle.dumps(spec))
        assert clone.build(1).nodes.keys() == spec.build(1).nodes.keys()


def test_batch_results_are_independent_objects_even_on_dedup():
    """Duplicate jobs share one compile but must never share identity:
    mutating one batch result cannot corrupt another (or the cache)."""
    c = CascadeCompiler(cache=CompileCache())
    app = ALL_APPS["vecadd"]
    cfg = PassConfig.full(place_moves=20)
    out = c.compile_batch([(app, cfg), (app, cfg), (app, cfg)])
    assert c.cache.stats()["misses"] == 1          # deduped to one compile
    assert len({id(r) for r in out}) == 3
    assert len({id(r.design) for r in out}) == 3
    baseline = json.dumps(out[1].summary())
    out[0].design.placement.clear()                # vandalize result 0
    out[0].pass_stats["poison"] = True
    out[2].design.unroll_copies = 999
    assert json.dumps(out[1].summary()) == baseline
    assert out[1].design.placement and "poison" not in out[1].pass_stats
    fresh = c.compile_batch([(app, cfg)])[0]       # cache entry unharmed
    assert fresh.design.placement and "poison" not in fresh.pass_stats


def test_invalid_backend_rejected():
    with pytest.raises(ValueError):
        CascadeCompiler(cache=CompileCache()).compile_batch(
            [(ALL_APPS["vecadd"], None)], backend="mpi")
    with pytest.raises(ValueError):
        CascadeCompiler(batch_backend="mpi")


# ---------------------------------------------------------------------------
# disk cache tier
# ---------------------------------------------------------------------------


def test_disk_cache_round_trip_across_cache_instances(tmp_path):
    """A fresh memory cache (a new process, in effect) is served from disk."""
    disk = DiskCache(root=tmp_path)
    c1 = CascadeCompiler(cache=CompileCache(disk=disk))
    app, cfg = ALL_APPS["vecadd"], PassConfig.full(place_moves=20)
    r1 = c1.compile(app, cfg)
    assert not r1.cache_hit and disk.stats()["puts"] == 1
    c2 = CascadeCompiler(cache=CompileCache(disk=DiskCache(root=tmp_path)))
    r2 = c2.compile(app, cfg)
    assert r2.cache_hit
    assert json.dumps(r2.summary()) == json.dumps(r1.summary())
    assert c2.cache.disk.stats()["hits"] == 1


def test_disk_cache_invalidated_on_schema_version_bump(tmp_path):
    disk = DiskCache(root=tmp_path)
    disk.put("k" * 64, {"payload": 1})
    assert DiskCache(root=tmp_path).get("k" * 64) == {"payload": 1}
    bumped = DiskCache(root=tmp_path, schema=DISK_SCHEMA_VERSION + 1)
    assert bumped.get("k" * 64) is None            # new namespace: cold
    assert bumped.stats()["misses"] == 1


def test_disk_cache_namespace_isolates_code_changes(tmp_path):
    a = DiskCache(root=tmp_path, namespace="aaaa")
    b = DiskCache(root=tmp_path, namespace="bbbb")
    a.put("key1", "from-a")
    assert b.get("key1") is None
    assert a.get("key1") == "from-a"


def test_disk_cache_corrupt_entry_is_a_miss(tmp_path):
    disk = DiskCache(root=tmp_path)
    disk.put("deadbeef", [1, 2, 3])
    path = disk._path("deadbeef")
    path.write_bytes(b"not a pickle")
    assert disk.get("deadbeef") is None
    assert not path.exists()                       # corrupt entry removed


def test_disk_cache_bounded_size_evicts_oldest(tmp_path):
    import os
    import time as _time
    disk = DiskCache(root=tmp_path, max_bytes=4096)
    for i in range(8):
        disk.put(f"key{i}", os.urandom(400).hex())   # ~900B pickled
        _time.sleep(0.01)                            # distinct mtimes
    assert disk.size_bytes() <= 4096
    assert disk.stats()["evictions"] > 0
    assert disk.get("key7") is not None              # newest survives


def test_disk_cache_sweeps_stale_tmp_orphans(tmp_path):
    """A process killed mid-put strands a .tmp file; the next eviction
    sweep removes it once it is clearly not an in-flight write."""
    import os
    disk = DiskCache(root=tmp_path, max_bytes=1)    # every put trims
    orphan = disk.dir / "orphan.tmp"
    orphan.write_bytes(b"stranded")
    old = 120.0
    os.utime(orphan, (orphan.stat().st_atime - old,
                      orphan.stat().st_mtime - old))
    fresh = disk.dir / "inflight.tmp"
    fresh.write_bytes(b"writing")
    disk.put("key", "value")
    assert not orphan.exists()
    assert fresh.exists()                           # recent: left alone


def test_disk_cache_unpicklable_value_is_skipped(tmp_path):
    disk = DiskCache(root=tmp_path)
    disk.put("k", lambda: None)
    assert disk.stats()["put_errors"] == 1 and len(disk) == 0


# ---------------------------------------------------------------------------
# env-var config plumbing
# ---------------------------------------------------------------------------


def test_cache_dir_env_override(monkeypatch, tmp_path):
    monkeypatch.setenv("CASCADE_CACHE_DIR", str(tmp_path / "custom"))
    assert cache_dir() == tmp_path / "custom"
    disk = DiskCache()
    assert str(disk.dir).startswith(str(tmp_path / "custom"))
    monkeypatch.delenv("CASCADE_CACHE_DIR")
    assert cache_dir().name == "cascade-repro"


def test_worker_count_env_override(monkeypatch):
    monkeypatch.setenv("CASCADE_WORKERS", "3")
    assert worker_count() == 3
    monkeypatch.setenv("CASCADE_WORKERS", "not-a-number")
    assert worker_count(jobs=2) <= 2               # falls back, job-clamped
    monkeypatch.delenv("CASCADE_WORKERS")
    assert 1 <= worker_count() <= 8


def test_worker_count_env_clamped_to_jobs(monkeypatch):
    """Regression: the env path must honour the docstring's "never more
    than jobs" clamp — CASCADE_WORKERS=8 with a 2-job batch is 2 workers,
    not 8 idle ones."""
    monkeypatch.setenv("CASCADE_WORKERS", "8")
    assert worker_count(jobs=2) == 2
    assert worker_count(jobs=1) == 1
    assert worker_count(jobs=16) == 8              # env still caps upward
    assert worker_count() == 8                     # no jobs: env verbatim
    monkeypatch.setenv("CASCADE_WORKERS", "0")
    assert worker_count(jobs=4) == 1               # floor stays at 1


def test_compile_batch_honours_cascade_workers(monkeypatch):
    monkeypatch.setenv("CASCADE_WORKERS", "2")
    c = CascadeCompiler(cache=CompileCache())
    cfg = PassConfig.full(place_moves=20)
    c.compile_batch([(ALL_APPS["vecadd"], cfg), (ALL_APPS["ttv"], cfg)])
    assert c.last_batch["workers"] == 2
    # env value is still clamped to the job count (worker_count contract)
    c2 = CascadeCompiler(cache=CompileCache())
    c2.compile_batch([(ALL_APPS["vecadd"], cfg)])
    assert c2.last_batch["workers"] == 1
