"""Subprocess smokes of the CLI launchers — the exact commands README
documents must work end to end (fresh interpreter, fresh jax init)."""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow        # subprocess smokes: seconds each

ENV = {**os.environ, "PYTHONPATH": "src"}


def _run(args, timeout=600):
    return subprocess.run([sys.executable] + args, env=ENV, cwd=".",
                          capture_output=True, text=True, timeout=timeout)


def test_train_cli_smoke(tmp_path):
    r = _run(["-m", "repro.launch.train", "--arch", "llama3-8b", "--smoke",
              "--steps", "8", "--ckpt-dir", str(tmp_path / "ck"),
              "--ckpt-every", "4", "--fail-at", "6"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "restored@4" in r.stdout or "restored@" in r.stdout
    assert "loss" in r.stdout


def test_serve_cli_smoke():
    r = _run(["-m", "repro.launch.serve", "--arch", "rwkv6-7b", "--smoke",
              "--batch", "2", "--prompt-len", "8", "--gen", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tok/s" in r.stdout


def test_dryrun_cli_single_cell(tmp_path):
    r = _run(["-m", "repro.launch.dryrun", "--arch", "whisper-small",
              "--shape", "decode_32k", "--out", str(tmp_path)],
             timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "whisper-small x decode_32k" in r.stdout
    assert (tmp_path / "whisper-small_decode_32k_16x16.json").exists()


def test_quickstart_example():
    r = _run(["examples/quickstart.py"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "critical path ratio" in r.stdout
