"""Cascade paper tables/figures, one function per artifact.

Fig. 6  STA model accuracy vs SDF-like simulation
Fig. 7  incremental software pipelining, dense apps
Table I dense frequency / runtime / power (+ Fig. 8 EDP)
Fig. 9  flush-signal hardening
Fig. 10 incremental pipelining, sparse apps
Table II sparse frequency / runtime / power (+ Fig. 11 EDP)

Each returns a list of row-dicts and prints a CSV block; ``benchmarks.run``
drives them all and checks the paper's headline bands.

All tables compile through ``CascadeCompiler.compile_batch`` sharing one
content-hash compile cache, so the many (app, config) pairs the tables have
in common (e.g. the full/unpipelined pairs of Fig. 6 and Table I) compile
exactly once per invocation — and not at all on repeat invocations within
one process.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from typing import Optional

from benchmarks._util import apply_pnr_backend, print_batch_stats, print_csv
from repro.core.apps import ALL_APPS, DENSE_APPS, SPARSE_APPS
from repro.core.compiler import CascadeCompiler, PassConfig
from repro.core.sta import sdf_simulate_fmax

MOVES = 120          # SA moves/node: enough for stable results, CPU-friendly
FAST_MOVES = 40      # --fast: quick smoke-level tables


# ---------------------------------------------------------------------------


def sta_accuracy(compiler: CascadeCompiler, moves: int = MOVES) -> List[Dict]:
    """Fig. 6: STA-modeled clock period vs SDF-sim period per app/config."""
    apps = list(DENSE_APPS) + list(SPARSE_APPS)
    configs = (PassConfig.unpipelined(place_moves=moves),
               PassConfig.full(place_moves=moves))
    jobs = [(ALL_APPS[a], cfg) for a in apps for cfg in configs]
    results = compiler.compile_batch(jobs)
    rows = []
    errs_fast = []
    for (app, cfg), r in zip(((a, c) for a in apps for c in configs), results):
        sta_mhz = r.sta.max_freq_mhz
        sdf_mhz = sdf_simulate_fmax(r.design, compiler.timing, seed=1)
        err = abs(sdf_mhz - sta_mhz) / sdf_mhz
        if sdf_mhz > 500:
            errs_fast.append(err)
        rows.append({"app": app,
                     "pipelined": int(cfg.compute_pipelining),
                     "sta_mhz": round(sta_mhz, 1),
                     "sdf_mhz": round(sdf_mhz, 1),
                     "err_pct": round(100 * err, 1)})
    mean_fast = 100 * float(np.mean(errs_fast)) if errs_fast else 0.0
    rows.append({"app": "MEAN>500MHz", "pipelined": "",
                 "sta_mhz": "", "sdf_mhz": "",
                 "err_pct": round(mean_fast, 1)})
    print_csv(rows, "Fig6_sta_accuracy (paper: ~13% mean err above 500 MHz)")
    return rows


def _dense_stages(moves: int):
    return [
        ("unpipelined", PassConfig.unpipelined(place_moves=moves)),
        ("+compute", PassConfig(compute_pipelining=True,
                                broadcast_pipelining=False,
                                placement_alpha=1.0, post_pnr=False,
                                low_unroll_dup=False, harden_flush=True,
                                place_moves=moves)),
        ("+broadcast", PassConfig(broadcast_pipelining=True,
                                  placement_alpha=1.0, post_pnr=False,
                                  low_unroll_dup=False, harden_flush=True,
                                  place_moves=moves)),
        ("+placement", PassConfig(broadcast_pipelining=True, post_pnr=False,
                                  low_unroll_dup=False, harden_flush=True,
                                  place_moves=moves)),
        ("+post_pnr", PassConfig(broadcast_pipelining=True,
                                 low_unroll_dup=False, harden_flush=True,
                                 place_moves=moves)),
        ("+low_unroll", PassConfig.full(place_moves=moves)),
    ]


def dense_incremental(compiler: CascadeCompiler,
                      moves: int = MOVES) -> List[Dict]:
    """Fig. 7: technique-by-technique runtime on the dense apps."""
    stages = _dense_stages(moves)
    pairs = [(app, name, cfg) for app in DENSE_APPS for name, cfg in stages]
    results = compiler.compile_batch([(ALL_APPS[a], cfg)
                                      for a, _, cfg in pairs])
    rows = []
    base_ms: Dict[str, float] = {}
    for (app, name, _), r in zip(pairs, results):
        ms = r.power.runtime_s * 1e3
        base_ms.setdefault(app, ms)
        rows.append({"app": app, "stage": name,
                     "freq_mhz": round(r.sta.max_freq_mhz, 1),
                     "runtime_ms": round(ms, 3),
                     "runtime_vs_base": round(ms / base_ms[app], 4)})
    print_csv(rows, "Fig7_dense_incremental")
    return rows


def dense_table(compiler: CascadeCompiler, moves: int = MOVES) -> List[Dict]:
    """Table I + Fig. 8: unpipelined vs fully pipelined dense apps."""
    apps = list(DENSE_APPS)
    jobs = [(ALL_APPS[a], cfg) for a in apps
            for cfg in (PassConfig.unpipelined(place_moves=moves),
                        PassConfig.full(place_moves=moves))]
    results = compiler.compile_batch(jobs)
    rows = []
    for i, app in enumerate(apps):
        r0, r1 = results[2 * i], results[2 * i + 1]
        cp_ratio = r0.sta.critical_path_ns / r1.sta.critical_path_ns
        edp_ratio = r0.power.edp_js / r1.power.edp_js
        rt_drop = 100 * (1 - r1.power.runtime_s / r0.power.runtime_s)
        rows.append({
            "app": app,
            "unpip_mhz": round(r0.sta.max_freq_mhz, 0),
            "pip_mhz": round(r1.sta.max_freq_mhz, 0),
            "unpip_ms": round(r0.power.runtime_s * 1e3, 2),
            "pip_ms": round(r1.power.runtime_s * 1e3, 2),
            "unpip_mw": round(r0.power.power_mw, 0),
            "pip_mw": round(r1.power.power_mw, 0),
            "cp_ratio": round(cp_ratio, 1),
            "edp_ratio": round(edp_ratio, 1),
            "runtime_drop_pct": round(rt_drop, 1),
        })
    print_csv(rows, "TableI_Fig8_dense (paper: CP 7-34x, EDP 7-190x, "
                 "runtime -84..-97%)")
    return rows


def flush_hardening(compiler: CascadeCompiler,
                    moves: int = MOVES) -> List[Dict]:
    """Fig. 9: software-routed vs hardened flush broadcast."""
    apps = list(DENSE_APPS)
    jobs = [(ALL_APPS[a], PassConfig.full(place_moves=moves,
                                          harden_flush=hard))
            for a in apps for hard in (False, True)]
    results = compiler.compile_batch(jobs)
    rows = []
    for i, app in enumerate(apps):
        soft, hard = results[2 * i], results[2 * i + 1]
        drop = 100 * (1 - hard.power.runtime_s / soft.power.runtime_s)
        rows.append({"app": app,
                     "soft_mhz": round(soft.sta.max_freq_mhz, 1),
                     "hard_mhz": round(hard.sta.max_freq_mhz, 1),
                     "runtime_drop_pct": round(drop, 1)})
    print_csv(rows, "Fig9_flush_hardening (paper: runtime -31..-56%)")
    return rows


def sparse_incremental(compiler: CascadeCompiler,
                       moves: int = MOVES) -> List[Dict]:
    """Fig. 10: sparse apps — compute pipelining is always on; placement
    optimization and post-PnR pipelining are applied incrementally."""
    stages = [
        ("compute_only", PassConfig(broadcast_pipelining=False,
                                    placement_alpha=1.0, post_pnr=False,
                                    low_unroll_dup=False, place_moves=moves)),
        ("+placement", PassConfig(broadcast_pipelining=False, post_pnr=False,
                                  low_unroll_dup=False, place_moves=moves)),
        ("+post_pnr", PassConfig(broadcast_pipelining=False,
                                 low_unroll_dup=False, place_moves=moves)),
    ]
    pairs = [(app, name, cfg) for app in SPARSE_APPS for name, cfg in stages]
    results = compiler.compile_batch([(ALL_APPS[a], cfg)
                                      for a, _, cfg in pairs])
    rows = []
    base_us: Dict[str, float] = {}
    for (app, name, _), r in zip(pairs, results):
        us = r.power.runtime_s * 1e6
        base_us.setdefault(app, us)
        rows.append({"app": app, "stage": name,
                     "freq_mhz": round(r.sta.max_freq_mhz, 1),
                     "runtime_us": round(us, 3),
                     "runtime_vs_base": round(us / base_us[app], 4)})
    print_csv(rows, "Fig10_sparse_incremental")
    return rows


def sparse_table(compiler: CascadeCompiler, moves: int = MOVES) -> List[Dict]:
    """Table II + Fig. 11: compute-pipelined vs fully pipelined sparse."""
    compute_only = PassConfig(broadcast_pipelining=False,
                              placement_alpha=1.0, post_pnr=False,
                              low_unroll_dup=False, place_moves=moves)
    apps = list(SPARSE_APPS)
    jobs = [(ALL_APPS[a], cfg) for a in apps
            for cfg in (compute_only, PassConfig.full(place_moves=moves))]
    results = compiler.compile_batch(jobs)
    rows = []
    for i, app in enumerate(apps):
        r0, r1 = results[2 * i], results[2 * i + 1]
        rows.append({
            "app": app,
            "compute_mhz": round(r0.sta.max_freq_mhz, 0),
            "full_mhz": round(r1.sta.max_freq_mhz, 0),
            "compute_us": round(r0.power.runtime_s * 1e6, 2),
            "full_us": round(r1.power.runtime_s * 1e6, 2),
            "cp_ratio": round(r0.sta.critical_path_ns /
                              r1.sta.critical_path_ns, 2),
            "edp_ratio": round(r0.power.edp_js / r1.power.edp_js, 2),
            "runtime_drop_pct": round(
                100 * (1 - r1.power.runtime_s / r0.power.runtime_s), 1),
        })
    print_csv(rows, "TableII_Fig11_sparse (paper: CP 2-4.4x, EDP 1.5-4.2x, "
                 "runtime -29..-65%)")
    return rows


# versus-unpipelined sparse ratios (paper's abstract quotes both baselines)
def run_all(fast: bool = False, backend: str = "auto",
            workers: Optional[int] = None,
            backend_pnr: Optional[str] = None) -> Dict[str, List[Dict]]:
    moves = FAST_MOVES if fast else MOVES
    c = apply_pnr_backend(
        CascadeCompiler(batch_backend=backend, batch_workers=workers),
        backend_pnr)
    t0 = time.time()
    out = {}
    for name, fn in (("sta_accuracy", sta_accuracy),
                     ("dense_incremental", dense_incremental),
                     ("dense_table", dense_table),
                     ("flush_hardening", flush_hardening),
                     ("sparse_incremental", sparse_incremental),
                     ("sparse_table", sparse_table)):
        out[name] = fn(c, moves)
        print_batch_stats(c, name)
    print(f"\n[cascade_tables] total {time.time() - t0:.1f}s "
          f"cache {c.cache.stats()}")
    return out
