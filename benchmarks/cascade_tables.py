"""Cascade paper tables/figures, one function per artifact.

Fig. 6  STA model accuracy vs SDF-like simulation
Fig. 7  incremental software pipelining, dense apps
Table I dense frequency / runtime / power (+ Fig. 8 EDP)
Fig. 9  flush-signal hardening
Fig. 10 incremental pipelining, sparse apps
Table II sparse frequency / runtime / power (+ Fig. 11 EDP)

Each returns a list of row-dicts and prints a CSV block; ``benchmarks.run``
drives them all and checks the paper's headline bands.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.apps import ALL_APPS, DENSE_APPS, SPARSE_APPS
from repro.core.compiler import CascadeCompiler, PassConfig
from repro.core.sta import sdf_simulate_fmax

MOVES = 120          # SA moves/node: enough for stable results, CPU-friendly


def _print(rows: List[Dict], name: str):
    if not rows:
        return
    cols = list(rows[0])
    print(f"\n== {name} ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


# ---------------------------------------------------------------------------


def sta_accuracy(compiler: CascadeCompiler) -> List[Dict]:
    """Fig. 6: STA-modeled clock period vs SDF-sim period per app/config."""
    rows = []
    errs_fast = []
    for app in list(DENSE_APPS) + list(SPARSE_APPS):
        for cfg in (PassConfig.unpipelined(place_moves=MOVES),
                    PassConfig.full(place_moves=MOVES)):
            r = compiler.compile(ALL_APPS[app], cfg)
            sta_mhz = r.sta.max_freq_mhz
            sdf_mhz = sdf_simulate_fmax(r.design, compiler.timing, seed=1)
            err = abs(sdf_mhz - sta_mhz) / sdf_mhz
            if sdf_mhz > 500:
                errs_fast.append(err)
            rows.append({"app": app,
                         "pipelined": int(cfg.compute_pipelining),
                         "sta_mhz": round(sta_mhz, 1),
                         "sdf_mhz": round(sdf_mhz, 1),
                         "err_pct": round(100 * err, 1)})
    mean_fast = 100 * float(np.mean(errs_fast)) if errs_fast else 0.0
    rows.append({"app": "MEAN>500MHz", "pipelined": "",
                 "sta_mhz": "", "sdf_mhz": "",
                 "err_pct": round(mean_fast, 1)})
    _print(rows, "Fig6_sta_accuracy (paper: ~13% mean err above 500 MHz)")
    return rows


def dense_incremental(compiler: CascadeCompiler) -> List[Dict]:
    """Fig. 7: technique-by-technique runtime on the dense apps."""
    stages = [
        ("unpipelined", PassConfig.unpipelined()),
        ("+compute", PassConfig(compute_pipelining=True,
                                broadcast_pipelining=False,
                                placement_alpha=1.0, post_pnr=False,
                                low_unroll_dup=False, harden_flush=True)),
        ("+broadcast", PassConfig(broadcast_pipelining=True,
                                  placement_alpha=1.0, post_pnr=False,
                                  low_unroll_dup=False, harden_flush=True)),
        ("+placement", PassConfig(broadcast_pipelining=True, post_pnr=False,
                                  low_unroll_dup=False, harden_flush=True)),
        ("+post_pnr", PassConfig(broadcast_pipelining=True,
                                 low_unroll_dup=False, harden_flush=True)),
        ("+low_unroll", PassConfig.full()),
    ]
    rows = []
    for app in DENSE_APPS:
        base_ms = None
        for name, cfg in stages:
            cfg.place_moves = MOVES
            r = compiler.compile(ALL_APPS[app], cfg)
            ms = r.power.runtime_s * 1e3
            if base_ms is None:
                base_ms = ms
            rows.append({"app": app, "stage": name,
                         "freq_mhz": round(r.sta.max_freq_mhz, 1),
                         "runtime_ms": round(ms, 3),
                         "runtime_vs_base": round(ms / base_ms, 4)})
    _print(rows, "Fig7_dense_incremental")
    return rows


def dense_table(compiler: CascadeCompiler) -> List[Dict]:
    """Table I + Fig. 8: unpipelined vs fully pipelined dense apps."""
    rows = []
    for app in DENSE_APPS:
        r0 = compiler.compile(ALL_APPS[app],
                              PassConfig.unpipelined(place_moves=MOVES))
        r1 = compiler.compile(ALL_APPS[app],
                              PassConfig.full(place_moves=MOVES))
        cp_ratio = r0.sta.critical_path_ns / r1.sta.critical_path_ns
        edp_ratio = r0.power.edp_js / r1.power.edp_js
        rt_drop = 100 * (1 - r1.power.runtime_s / r0.power.runtime_s)
        rows.append({
            "app": app,
            "unpip_mhz": round(r0.sta.max_freq_mhz, 0),
            "pip_mhz": round(r1.sta.max_freq_mhz, 0),
            "unpip_ms": round(r0.power.runtime_s * 1e3, 2),
            "pip_ms": round(r1.power.runtime_s * 1e3, 2),
            "unpip_mw": round(r0.power.power_mw, 0),
            "pip_mw": round(r1.power.power_mw, 0),
            "cp_ratio": round(cp_ratio, 1),
            "edp_ratio": round(edp_ratio, 1),
            "runtime_drop_pct": round(rt_drop, 1),
        })
    _print(rows, "TableI_Fig8_dense (paper: CP 7-34x, EDP 7-190x, "
                 "runtime -84..-97%)")
    return rows


def flush_hardening(compiler: CascadeCompiler) -> List[Dict]:
    """Fig. 9: software-routed vs hardened flush broadcast."""
    rows = []
    for app in DENSE_APPS:
        soft = compiler.compile(ALL_APPS[app], PassConfig.full(
            place_moves=MOVES, harden_flush=False))
        hard = compiler.compile(ALL_APPS[app], PassConfig.full(
            place_moves=MOVES, harden_flush=True))
        drop = 100 * (1 - hard.power.runtime_s / soft.power.runtime_s)
        rows.append({"app": app,
                     "soft_mhz": round(soft.sta.max_freq_mhz, 1),
                     "hard_mhz": round(hard.sta.max_freq_mhz, 1),
                     "runtime_drop_pct": round(drop, 1)})
    _print(rows, "Fig9_flush_hardening (paper: runtime -31..-56%)")
    return rows


def sparse_incremental(compiler: CascadeCompiler) -> List[Dict]:
    """Fig. 10: sparse apps — compute pipelining is always on; placement
    optimization and post-PnR pipelining are applied incrementally."""
    stages = [
        ("compute_only", PassConfig(broadcast_pipelining=False,
                                    placement_alpha=1.0, post_pnr=False,
                                    low_unroll_dup=False)),
        ("+placement", PassConfig(broadcast_pipelining=False, post_pnr=False,
                                  low_unroll_dup=False)),
        ("+post_pnr", PassConfig(broadcast_pipelining=False,
                                 low_unroll_dup=False)),
    ]
    rows = []
    for app in SPARSE_APPS:
        base_us = None
        for name, cfg in stages:
            cfg.place_moves = MOVES
            r = compiler.compile(ALL_APPS[app], cfg)
            us = r.power.runtime_s * 1e6
            if base_us is None:
                base_us = us
            rows.append({"app": app, "stage": name,
                         "freq_mhz": round(r.sta.max_freq_mhz, 1),
                         "runtime_us": round(us, 3),
                         "runtime_vs_base": round(us / base_us, 4)})
    _print(rows, "Fig10_sparse_incremental")
    return rows


def sparse_table(compiler: CascadeCompiler) -> List[Dict]:
    """Table II + Fig. 11: compute-pipelined vs fully pipelined sparse."""
    compute_only = PassConfig(broadcast_pipelining=False,
                              placement_alpha=1.0, post_pnr=False,
                              low_unroll_dup=False, place_moves=MOVES)
    rows = []
    for app in SPARSE_APPS:
        r0 = compiler.compile(ALL_APPS[app], compute_only)
        r1 = compiler.compile(ALL_APPS[app],
                              PassConfig.full(place_moves=MOVES))
        rows.append({
            "app": app,
            "compute_mhz": round(r0.sta.max_freq_mhz, 0),
            "full_mhz": round(r1.sta.max_freq_mhz, 0),
            "compute_us": round(r0.power.runtime_s * 1e6, 2),
            "full_us": round(r1.power.runtime_s * 1e6, 2),
            "cp_ratio": round(r0.sta.critical_path_ns /
                              r1.sta.critical_path_ns, 2),
            "edp_ratio": round(r0.power.edp_js / r1.power.edp_js, 2),
            "runtime_drop_pct": round(
                100 * (1 - r1.power.runtime_s / r0.power.runtime_s), 1),
        })
    _print(rows, "TableII_Fig11_sparse (paper: CP 2-4.4x, EDP 1.5-4.2x, "
                 "runtime -29..-65%)")
    return rows


# versus-unpipelined sparse ratios (paper's abstract quotes both baselines)
def run_all() -> Dict[str, List[Dict]]:
    c = CascadeCompiler()
    t0 = time.time()
    out = {
        "sta_accuracy": sta_accuracy(c),
        "dense_incremental": dense_incremental(c),
        "dense_table": dense_table(c),
        "flush_hardening": flush_hardening(c),
        "sparse_incremental": sparse_incremental(c),
        "sparse_table": sparse_table(c),
    }
    print(f"\n[cascade_tables] total {time.time() - t0:.1f}s")
    return out
