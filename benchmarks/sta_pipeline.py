"""Vectorized STA / incremental re-timing benchmark: scalar vs numpy vs jax.

Times the post-PnR register-insertion loop (paper Section V-D) — the
inner loop of every power-cap and Pareto-frontier sweep — under each
``sta_backend``, on the routed benchmark designs.  The contract is
*asserted*, not just printed:

* every engine's one-shot STA report is bit-identical to the scalar
  oracle (critical path ns, reconstruction, arrival maps, segments);
* the pipelining loop is byte-identical across engines (same histories,
  stop reasons, register placements);
* the numpy incremental engine reaches >= 5x warm speedup over the
  scalar loop on the headline app (harris x4).

Timing protocol: the routed design and the lowering are built *outside*
the timer (the lowering is structure-only, so one serves every run); a
throwaway warm run per backend absorbs one-time costs (jax pays its XLA
compile there); the reported number is the best of three timed runs of
the full loop on a fresh deepcopy.

The end-to-end section sweeps a small Pareto grid through
``explore_frontier`` with scalar vs numpy engines — every frontier
point shares one lowering — and asserts identical frontiers.

A capture-hoist note for the archaeology: profiling this loop showed the
old per-round ``DesignCheckpoint.capture`` (a full reg-state snapshot,
O(total hops)) dominating round overhead; rounds now record a positional
``_RoundDelta`` (branch counts + the sites actually added) and only the
power-cap hook still captures full checkpoints, at its accept points.

    PYTHONPATH=src python -m benchmarks.sta_pipeline [--fast]
        [--bench-out BENCH_sta.json]

``benchmarks.run`` drives this as the ``sta`` section and folds the rows
into its trajectory record; CI uploads ``BENCH_sta.json`` from the
perf-smoke lane.
"""

from __future__ import annotations

import argparse
import copy
import time
from typing import Dict, List, Optional, Tuple

from benchmarks._util import append_bench_record, print_csv

#: (app, unroll) pairs, smallest to largest; harris x4 is the headline
#: (the ISSUE's >= 5x pipelining-loop criterion is checked against it).
BENCH_APPS = (("gaussian", 1), ("camera", 2), ("harris", 1),
              ("mttkrp", 2), ("harris", 4))
FAST_APPS = (("gaussian", 1), ("harris", 4))
HEADLINE = "harrisx4"
SPEEDUP_BAR = 5.0
REPEATS = 3


def _routed(compiler, app: str, mult: int):
    from repro.core import ALL_APPS, PassConfig

    art = compiler.compile_to_stage(ALL_APPS[app], PassConfig(),
                                    stage="routed", unroll=mult)
    return art.state["design"], art.state["place_timing"]


def _assert_reports_identical(name: str, ref, got) -> None:
    ok = (got.critical_path_ns == ref.critical_path_ns
          and got.max_freq_mhz == ref.max_freq_mhz
          and got.n_segments == ref.n_segments
          and got.critical_path == ref.critical_path
          and got.arrival_out == ref.arrival_out)
    assert ok, f"{name}: vectorized STA diverged from the scalar oracle"


def _loop_state(design, res) -> Tuple:
    return (tuple(res.history), res.stop_reason, res.registers_added,
            tuple(sorted((k, tuple(sorted(rb.reg_hops)))
                         for k, rb in design.routes.items())),
            tuple(b.n_regs for b in design.netlist.branches))


def _time_loop(design, tm, backend: str, lowering=None) -> Tuple[float, Tuple]:
    """Best-of-N wall time for one full pipelining loop; deepcopy and
    lowering stay outside the timer."""
    from repro.core import post_pnr_pipeline

    best, state = float("inf"), None
    for _ in range(1 + REPEATS):          # first run is the warmup
        d = copy.deepcopy(design)
        t0 = time.perf_counter()
        res = post_pnr_pipeline(d, tm, sta_backend=backend,
                                lowering=lowering)
        dt = time.perf_counter() - t0
        if state is None:                 # warmup: keep the state, not time
            state = _loop_state(d, res)
            continue
        assert _loop_state(d, res) == state, \
            f"{backend}: loop not deterministic across runs"
        best = min(best, dt)
    return best, state


def bench_pipelining(fast: bool = False) -> List[Dict]:
    from repro.core import (CascadeCompiler, CompileCache, analyze,
                            lower_design)

    compiler = CascadeCompiler(cache=CompileCache())
    try:
        import jax  # noqa: F401
        backends = ("numpy", "jax")
    except Exception:                     # pragma: no cover - env dependent
        backends = ("numpy",)

    rows: List[Dict] = []
    for app, mult in (FAST_APPS if fast else BENCH_APPS):
        name = f"{app}x{mult}"
        design, tm = _routed(compiler, app, mult)
        ref = analyze(design, tm)
        for b in backends:                # one-shot bit-identity gate
            _assert_reports_identical(name, ref,
                                      analyze(design, tm, backend=b))
        lowering = lower_design(design, tm)
        t_scalar, s_scalar = _time_loop(design, tm, "scalar")
        row: Dict = {"app": name,
                     "routes": len(design.routes),
                     "rounds": len(s_scalar[0]),
                     "scalar_s": round(t_scalar, 4)}
        for b in backends:
            t_vec, s_vec = _time_loop(design, tm, b, lowering=lowering)
            assert s_vec == s_scalar, \
                f"{name}: {b} loop diverged from the scalar loop"
            row[f"{b}_s"] = round(t_vec, 4)
            row[f"{b}_speedup"] = round(t_scalar / t_vec, 2)
        rows.append(row)
    print_csv(rows, "post-PnR pipelining loop, scalar vs vectorized STA "
                    "(wall seconds, best of %d)" % REPEATS)
    return rows


def bench_explore(fast: bool = False) -> Dict:
    """End-to-end: a Pareto sweep with every frontier point re-timed by
    the shared-lowering numpy engine vs the scalar oracle."""
    from repro.core import (ALL_APPS, CascadeCompiler, CompileCache,
                            ExploreSpec, explore_frontier)

    app, mult = ("harris", 1) if fast else ("harris", 4)
    compiler = CascadeCompiler(cache=CompileCache())
    design, tm = _routed(compiler, app, mult)
    iters = ALL_APPS[app].iterations_for(mult)
    spec = ExploreSpec(register_budgets=(2, 6, None))

    def run(backend: str) -> Tuple[float, Tuple]:
        d = copy.deepcopy(design)
        t0 = time.perf_counter()
        fr = explore_frontier(d, tm, compiler.energy, iters, spec,
                              sta_backend=backend)
        dt = time.perf_counter() - t0
        pts = tuple(tuple(sorted(p.scaled().items()))
                    for p in fr.all_points())
        return dt, (pts, _loop_state(d, fr.selected.result.post_pnr))

    t_scalar, f_scalar = run("scalar")
    run("numpy")                          # warmup (lowering + caches)
    t_numpy, f_numpy = run("numpy")
    assert f_numpy == f_scalar, "explore frontier diverged across engines"
    out = {"app": f"{app}x{mult}", "points": len(spec.points()),
           "scalar_s": round(t_scalar, 3), "numpy_s": round(t_numpy, 3),
           "speedup": round(t_scalar / t_numpy, 2)}
    print(f"[sta_pipeline] explore_frontier {out['app']} "
          f"({out['points']} points): scalar {out['scalar_s']}s, "
          f"numpy {out['numpy_s']}s ({out['speedup']}x)")
    return out


def run_all(fast: bool = False) -> Dict:
    rows = bench_pipelining(fast=fast)
    headline = next((r for r in rows if r["app"] == HEADLINE), rows[-1])
    speedup = headline.get("numpy_speedup", 0.0)
    print(f"[sta_pipeline] {headline['app']}: pipelining loop "
          f"{speedup}x warm (numpy incremental vs scalar)")
    assert speedup >= SPEEDUP_BAR, (
        f"{headline['app']}: numpy incremental loop speedup {speedup}x "
        f"below the {SPEEDUP_BAR}x bar")
    explore = bench_explore(fast=fast)
    return {"apps": rows, "headline_speedup": speedup, "explore": explore}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smallest + headline app only")
    ap.add_argument("--bench-out", default="BENCH_sta.json",
                    help="trajectory file to append the results to")
    args = ap.parse_args()
    out = run_all(fast=args.fast)
    append_bench_record(args.bench_out, {"sta_pipeline": out})


if __name__ == "__main__":
    main()
