"""Online multi-tenant serving benchmark: FabricScheduler vs static packing.

Replays fragmentation-heavy traffic traces — overlapping app sessions
that arrive and depart at different times, carving holes into the fabric
— through one shared :class:`~repro.core.service.CompileService`, twice:

* **online**: the :class:`~repro.core.sched.FabricScheduler` (2D
  rectangle admission, compacting re-pack on fragmentation, objective-
  scored eviction, waitlist readmission), and
* **static**: ``compile_multi``-style full-height column strips in
  arrival order, no re-pack, no eviction (:func:`~repro.core.sched.
  evaluate_static`).

Both legs use identical epoch accounting, so the summed
``TrafficReport.objective()`` totals and rejection counts are directly
comparable; the acceptance check is that online beats static (higher
objective or fewer rejections) on every fragmentation-heavy trace.

    PYTHONPATH=src python -m benchmarks.serve_online [--fast]
        [--trace NAME] [--seed N] [--bench-out BENCH_serve.json]

Each run appends one record per trace to ``BENCH_serve.json`` (the
online-serving trajectory file, mirroring ``BENCH_multi.json``).  The
service knobs come from the driver-side env seams
(``CASCADE_SERVICE_BATCH_WINDOW_MS`` / ``CASCADE_SERVICE_MAX_BATCH`` /
``CASCADE_SCHED_LATENCY_WEIGHT``) — the library itself never reads them.
"""

from __future__ import annotations

import argparse
import dataclasses
import random
import time
from typing import Dict, Optional, Tuple

from benchmarks._util import append_bench_record, print_csv
from repro.core import (CompileService, FabricScheduler, PassConfig,
                        evaluate_static, sched_latency_weight,
                        service_batch_window_s, service_max_batch,
                        session_trace)
from repro.core.apps import ALL_APPS
from repro.core.traffic import TrafficTrace

MOVES = 100
FAST_MOVES = 40

#: width-4 tenants + the width-8 harris pipeline that needs two adjacent
#: MEM-column groups on the default 32x16 fabric — arrivals after a
#: departure wave only fit once the scheduler compacts the survivors.
NARROW_APPS = ("vecadd", "elemmul", "ttv", "mttkrp")
WIDE_APP = "harris"
PERIOD = 100_000


def _alias(base: str, name: str):
    return dataclasses.replace(ALL_APPS[base], name=name)


def wide_waves_trace() -> Tuple[TrafficTrace, Dict]:
    """Deterministic fragmentation: four width-4 tenants fill the column
    groups, the 2nd and 4th depart (non-adjacent holes), then a width-8
    tenant arrives — admissible online only via the compacting re-pack."""
    sessions = [
        ("a0", 0, 20_000_000),
        ("a1", 100, 5_000_000),
        ("a2", 200, 20_000_000),
        ("a3", 300, 6_000_000),
        ("w1", 8_000_000, 20_000_000),
    ]
    apps = {"a0": _alias("vecadd", "a0"), "a1": _alias("elemmul", "a1"),
            "a2": _alias("ttv", "a2"), "a3": _alias("mttkrp", "a3"),
            "w1": _alias(WIDE_APP, "w1")}
    return session_trace(sessions, period=PERIOD, name="wide_waves"), apps


def churn_trace(n_sessions: int, seed: int) -> Tuple[TrafficTrace, Dict]:
    """Randomized session churn around fabric capacity: overlapping
    narrow and wide tenants arriving/departing continuously."""
    rng = random.Random(seed)
    bases = list(NARROW_APPS) + [WIDE_APP]
    apps, sessions, t = {}, [], 0
    for i in range(n_sessions):
        base = rng.choice(bases)
        name = f"{base}_s{i}"
        apps[name] = _alias(base, name)
        t += rng.randint(100_000, 400_000)
        sessions.append((name, t, t + rng.randint(300_000, 1_500_000)))
    return session_trace(sessions, period=PERIOD,
                         name=f"churn{seed}"), apps


def run_trace(trace: TrafficTrace, apps: Dict, moves: int = MOVES,
              latency_weight: Optional[float] = None,
              bench_out: Optional[str] = "BENCH_serve.json") -> Dict:
    weight = sched_latency_weight() if latency_weight is None \
        else latency_weight
    cfg = PassConfig.full(place_moves=moves)
    configs = {name: cfg for name in trace.arrivals}
    svc = CompileService(batch_window_s=service_batch_window_s(),
                         max_batch=service_max_batch()).start()
    try:
        t0 = time.perf_counter()
        online = FabricScheduler(service=svc, latency_weight=weight).run(
            trace, apps, configs=configs)
        t_online = time.perf_counter() - t0
        t0 = time.perf_counter()
        static = evaluate_static(trace, apps, service=svc,
                                 configs=configs, latency_weight=weight)
        t_static = time.perf_counter() - t0
        stats = svc.stats()
    finally:
        svc.stop()

    rows = []
    for out, wall in ((online, t_online), (static, t_static)):
        s = out.summary()
        rows.append({
            "policy": s["policy"],
            "objective": round(s["objective"], 1),
            "admitted": s["admitted"],
            "readmitted": s["readmitted"],
            "rejected": s["rejected"],
            "evicted": s["evicted"],
            "repacks": s["repacks"],
            "wall_s": round(wall, 2),
        })
    print_csv(rows, f"online vs static ({trace.name})")
    gain = online.objective - static.objective
    wins = (online.objective > static.objective
            or online.rejected < static.rejected)
    print(f"[serve] {trace.name}: objective {online.objective:,.0f} online "
          f"vs {static.objective:,.0f} static "
          f"({'+' if gain >= 0 else ''}{gain:,.0f}) | rejections "
          f"{online.rejected} vs {static.rejected} | "
          f"{'OK online wins' if wins else 'REGRESSION static wins'}")
    print(f"[serve] service: {stats['completed']} compiles, "
          f"{stats['dedup_inflight']} in-flight dedups, "
          f"{stats['batches']} batches, cache hit rate "
          f"{stats.get('cache', {}).get('hit_rate', 0.0)}, "
          f"pool {stats['pool']['entries']} pinned / "
          f"{stats['pool']['hits']} hits")

    record = {
        "trace": trace.name,
        "apps": len(trace.arrivals),
        "requests": trace.total_requests(),
        "moves": moves,
        "latency_weight": weight,
        "online": online.summary(),
        "static": static.summary(),
        "objective_gain": round(gain, 3),
        "rejection_delta": static.rejected - online.rejected,
        "online_wins": wins,
        "service": {
            "completed": stats["completed"],
            "failed": stats["failed"],
            "dedup_inflight": stats["dedup_inflight"],
            "batches": stats["batches"],
            "largest_batch": stats["largest_batch"],
            "cache_hit_rate": stats.get("cache", {}).get("hit_rate", 0.0),
            "pool": stats["pool"],
        },
        "online_seconds": round(t_online, 3),
        "static_seconds": round(t_static, 3),
    }
    if bench_out:
        append_bench_record(bench_out, record)
    return record


def run_all(fast: bool = False, seed: int = 3,
            bench_out: Optional[str] = "BENCH_serve.json") -> Dict:
    moves = FAST_MOVES if fast else MOVES
    traces = [wide_waves_trace(),
              churn_trace(16 if fast else 48, seed)]
    if not fast:
        traces.append(churn_trace(48, seed + 1))
    out = {}
    for trace, apps in traces:
        out[trace.name] = run_trace(trace, apps, moves=moves,
                                    bench_out=bench_out)
    wins = sum(1 for r in out.values() if r["online_wins"])
    print(f"\n[serve] online wins {wins}/{len(out)} fragmentation-heavy "
          f"traces")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller churn trace at reduced SA moves "
                         "(CI perf-smoke)")
    ap.add_argument("--trace", default=None,
                    choices=("wide_waves", "churn"),
                    help="run a single trace family (default: all)")
    ap.add_argument("--seed", type=int, default=3,
                    help="churn trace seed")
    ap.add_argument("--bench-out", default="BENCH_serve.json")
    args = ap.parse_args()
    moves = FAST_MOVES if args.fast else MOVES
    if args.trace == "wide_waves":
        trace, apps = wide_waves_trace()
        run_trace(trace, apps, moves=moves, bench_out=args.bench_out)
    elif args.trace == "churn":
        trace, apps = churn_trace(16 if args.fast else 48, args.seed)
        run_trace(trace, apps, moves=moves, bench_out=args.bench_out)
    else:
        run_all(fast=args.fast, seed=args.seed, bench_out=args.bench_out)


if __name__ == "__main__":
    main()
