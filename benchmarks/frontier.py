"""Frontier benchmark: in-compile Pareto sweep vs N independent compiles.

The ``"explore"`` schedule compiles the mapping/placement/routing prefix
once and forks the routed design across a (register budget x power cap)
grid; stage-artifact caching makes a *second* sweep skip even that prefix.
This bench quantifies both against the old way — N full compiles — and
verifies the frontier points are byte-identical to them:

    PYTHONPATH=src python -m benchmarks.frontier [--fast] [--app NAME]
        [--backend auto|thread|process] [--workers N] [--moves N]
        [--bench-out BENCH_frontier.json]

Each run appends a record to ``BENCH_frontier.json`` (wall clock for the
independent ladder, the cold sweep, and the warm-prefix sweep, plus the
frontier rows and the byte-identity verdict) so the trajectory is tracked
across runs and PRs, like ``BENCH_pnr.json`` for raw PnR.
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Sequence

from benchmarks._util import append_bench_record, print_batch_stats, print_csv
from repro.core import CascadeCompiler, CompileCache, ExploreSpec, PassConfig
from repro.core.apps import ALL_APPS

MOVES = 100
FAST_MOVES = 40
BUDGETS = (4, 16, 64, None)
FAST_BUDGETS = (8, 32, None)
CAP_FRACTIONS = (0.9, None)          # fractions of the uncapped power
FAST_CAP_FRACTIONS = (None,)


def _point_config(budget, cap, moves: int) -> PassConfig:
    """The config an independent compile of one sweep point uses."""
    if cap is not None:
        return PassConfig.power_capped(cap, post_pnr_budget=budget,
                                       place_moves=moves)
    return PassConfig.full(post_pnr_budget=budget, place_moves=moves)


def _metrics(r) -> tuple:
    return (r.sta.max_freq_mhz, r.power.power_mw, r.power.edp_js,
            r.design.netlist.added_registers())


def run_frontier(app: str = "unsharp", moves: int = MOVES,
                 budgets: Sequence[Optional[int]] = BUDGETS,
                 cap_fractions: Sequence[Optional[float]] = CAP_FRACTIONS,
                 backend: str = "auto", workers: Optional[int] = None,
                 bench_out: Optional[str] = "BENCH_frontier.json"
                 ) -> Dict[str, object]:
    spec_app = ALL_APPS[app]

    # -- the old way: one full compile per sweep point (cold, no caches) --
    cold = CascadeCompiler(cache=CompileCache(), stage_cache=CompileCache())
    t0 = time.perf_counter()
    base = cold.compile(spec_app, PassConfig.full(place_moves=moves),
                        use_cache=False)
    t_base = time.perf_counter() - t0
    caps = [None if f is None else base.power.power_mw * f
            for f in cap_fractions]
    points = [(b, c) for b in budgets for c in caps]

    independent: Dict[tuple, tuple] = {}
    t_independent = 0.0
    for b, c in points:
        if (b, c) == (None, None):
            independent[(b, c)] = _metrics(base)
            t_independent += t_base
            continue
        t0 = time.perf_counter()
        r = cold.compile(spec_app, _point_config(b, c, moves),
                         use_cache=False)
        t_independent += time.perf_counter() - t0
        independent[(b, c)] = _metrics(r)

    # -- the new way: one explore compile over the same grid --------------
    spec = ExploreSpec(register_budgets=tuple(budgets),
                       power_caps_mw=tuple(caps))
    sweep = CascadeCompiler(cache=CompileCache(), stage_cache=CompileCache(),
                            batch_backend=backend, batch_workers=workers)
    t0 = time.perf_counter()
    (rf,) = sweep.compile_batch(
        [(spec_app, PassConfig.frontier(spec, place_moves=moves))])
    t_frontier_cold = time.perf_counter() - t0
    print_batch_stats(sweep, f"frontier cold ({app})")

    # warm prefix: a different sweep over the same routed artifact (the
    # select policy is a post-PnR knob, so the final key misses while the
    # routed stage key hits)
    import dataclasses
    warm_spec = dataclasses.replace(spec, select="max_freq")
    t0 = time.perf_counter()
    (rw,) = sweep.compile_batch(
        [(spec_app, PassConfig.frontier(warm_spec, place_moves=moves))])
    t_frontier_warm = time.perf_counter() - t0
    print_batch_stats(sweep, f"frontier warm ({app})")
    assert rw.pass_stats.get("stage_resume") == "routed", \
        "warm sweep did not resume from the routed stage artifact"

    # -- verify: byte-identity per point + non-dominated frontier ---------
    byte_identical = True
    for (b, c) in points:
        pt = rf.frontier.point_for(b, c)
        got = (pt.freq_mhz, pt.power_mw, pt.edp_js, pt.registers_added)
        if got != independent[(b, c)]:
            byte_identical = False
            print(f"[frontier] MISMATCH at (budget={b}, cap={c}): "
                  f"sweep {got} vs independent {independent[(b, c)]}")

    rows: List[Dict] = []
    for p in rf.frontier.all_points():
        row = {"app": app, **p.scaled()}
        row["power_cap_mw"] = (round(row["power_cap_mw"], 2)
                               if row["power_cap_mw"] is not None else None)
        row["edp_ujs"] = round(row["edp_ujs"], 4)
        rows.append(row)
    print_csv(rows, "frontier: in-compile Pareto sweep (budgets x caps)")

    n = len(points)
    speedup_cold = t_independent / t_frontier_cold if t_frontier_cold else 0.0
    speedup_warm = t_independent / t_frontier_warm if t_frontier_warm else 0.0
    two_independent = 2.0 * t_independent / n    # 2 average full compiles
    print(f"[frontier] {app}: {n} points | independent {t_independent:.1f}s"
          f" | sweep cold {t_frontier_cold:.1f}s ({speedup_cold:.1f}x)"
          f" | sweep warm {t_frontier_warm:.1f}s ({speedup_warm:.1f}x)"
          f" | byte-identical: {byte_identical}"
          f" | non-dominated {len(rf.frontier.points)}/{n}"
          f" | warm sweep vs 2 compiles: {t_frontier_warm:.1f}s vs "
          f"{two_independent:.1f}s")

    record = {
        "app": app, "moves": moves, "points": n,
        "backend": sweep.last_batch.get("backend"),
        "workers": sweep.last_batch.get("workers"),
        "independent_seconds": round(t_independent, 3),
        "frontier_cold_seconds": round(t_frontier_cold, 3),
        "frontier_warm_seconds": round(t_frontier_warm, 3),
        "speedup_cold": round(speedup_cold, 2),
        "speedup_warm": round(speedup_warm, 2),
        "two_independent_seconds": round(two_independent, 3),
        "warm_under_two_independents": t_frontier_warm < two_independent,
        "byte_identical": byte_identical,
        "non_dominated": len(rf.frontier.points),
        "frontier": rows,
    }
    if bench_out:
        append_bench_record(bench_out, record)
    return record


def run_all(fast: bool = False, backend: str = "auto",
            workers: Optional[int] = None,
            bench_out: Optional[str] = "BENCH_frontier.json") -> Dict:
    return {"frontier": run_frontier(
        moves=FAST_MOVES if fast else MOVES,
        budgets=FAST_BUDGETS if fast else BUDGETS,
        cap_fractions=FAST_CAP_FRACTIONS if fast else CAP_FRACTIONS,
        backend=backend, workers=workers, bench_out=bench_out)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="unsharp",
                    help="dense app to sweep (default unsharp)")
    ap.add_argument("--fast", action="store_true",
                    help="3-point sweep at reduced SA moves (CI smoke)")
    ap.add_argument("--moves", type=int, default=None)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "thread", "process"))
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--bench-out", default="BENCH_frontier.json")
    args = ap.parse_args()
    run_frontier(
        app=args.app,
        moves=args.moves or (FAST_MOVES if args.fast else MOVES),
        budgets=FAST_BUDGETS if args.fast else BUDGETS,
        cap_fractions=FAST_CAP_FRACTIONS if args.fast else CAP_FRACTIONS,
        backend=args.backend, workers=args.workers,
        bench_out=args.bench_out)


if __name__ == "__main__":
    main()
