"""Roofline table from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and prints
the per-(arch x shape x mesh) three-term roofline with the dominant
bottleneck, MODEL_FLOPS ratio, and the one-line "what would move the
dominant term" note.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

NOTES = {
    ("collective", "train"): "shard seq over model (SP) + bf16/reduce-scatter "
                             "grad sync to cut all-reduce wire bytes",
    ("collective", "decode"): "re-shard KV cache (batch+head_dim) to kill "
                              "cache-update collectives",
    ("collective", "prefill"): "keep residual seq-sharded; all-gather only "
                               "around attention",
    ("memory", "train"): "less remat recompute / fuse norm+matmul reads",
    ("memory", "decode"): "cache layout: stream KV once; batch decode heads",
    ("memory", "prefill"): "stream KV blocks (flash) instead of score "
                           "materialization",
    ("compute", "train"): "already near the right wall: raise MXU "
                          "utilization via 128-aligned tiles",
    ("compute", "prefill"): "already compute-bound: pick bigger per-chip "
                            "tiles",
    ("compute", "decode"): "decode should not be compute-bound: check "
                           "redundant per-token recompute",
}


def load_cells(out_dir: str = "experiments/dryrun") -> List[Dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def run_all(out_dir: str = "experiments/dryrun") -> List[Dict]:
    cells = load_cells(out_dir)
    if not cells:
        print("[roofline] no dry-run artifacts found; run "
              "`python -m repro.launch.dryrun --all` first")
        return []
    rows = []
    print("\n== Roofline (single-pod 16x16; terms in seconds/step) ==")
    hdr = ("arch,shape,mesh,compute_s,memory_s,collective_s,bound,"
           "peak_GB,model/HLO_flops,roofline_frac,note")
    print(hdr)
    for c in cells:
        if c.get("mesh") != "16x16":
            continue
        if "skipped" in c:
            print(f"{c['arch']},{c['shape']},{c['mesh']},SKIP,,,,,,,"
                  f"\"{c['skipped'][:60]}\"")
            continue
        r = c.get("roofline", {})
        if not r:
            continue
        kind = ("train" if c["shape"].startswith("train") else
                "prefill" if "prefill" in c["shape"] else "decode")
        note = NOTES.get((r.get("bound"), kind), "")
        peak = c.get("memory", {}).get("peak_memory_in_bytes", 0) / 1e9
        row = {
            "arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
            "compute_s": f"{r['compute_s']:.4g}",
            "memory_s": f"{r['memory_s']:.4g}",
            "collective_s": f"{r['collective_s']:.4g}",
            "bound": r["bound"],
            "peak_GB": f"{peak:.2f}",
            "useful": c.get("useful_flop_ratio", ""),
            "frac": c.get("roofline_fraction", ""),
            "note": note,
        }
        rows.append(row)
        print(f"{row['arch']},{row['shape']},{row['mesh']},"
              f"{row['compute_s']},{row['memory_s']},{row['collective_s']},"
              f"{row['bound']},{row['peak_GB']},{row['useful']},"
              f"{row['frac']},\"{note}\"")
    # multi-pod feasibility recap
    n_multi = sum(1 for c in cells if c.get("mesh") == "2x16x16"
                  and "skipped" not in c)
    print(f"\n[roofline] multi-pod (2x16x16) cells compiled: {n_multi}")
    return rows
