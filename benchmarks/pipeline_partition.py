"""Cascade-guided pipeline partitioning vs naive equal-layer split
(beyond-paper: the paper's post-PnR register-insertion loop applied to
pipeline-parallel stage balancing).  Most interesting on heterogeneous
stacks: MoE interleave (llama4) and hybrid shared-attention (zamba2)."""

from __future__ import annotations

from typing import Dict, List

from repro.configs import ARCHS, SHAPES
from repro.distributed.pipeline import plan_for


def run_all() -> List[Dict]:
    rows = []
    shape = SHAPES["train_4k"]
    for arch in ("llama4-maverick-400b-a17b", "zamba2-2.7b",
                 "mistral-large-123b", "llama3-8b"):
        cfg = ARCHS[arch]
        plans = plan_for(cfg, shape, num_stages=4, chips_per_stage=64,
                         microbatches=8)
        cas, nai = plans["cascade"], plans["naive"]
        rows.append({
            "arch": arch,
            "naive_beat_ms": round(nai.beat_s * 1e3, 3),
            "cascade_beat_ms": round(cas.beat_s * 1e3, 3),
            "beat_speedup": round(nai.beat_s / cas.beat_s, 3),
            "makespan_speedup": round(nai.makespan_s / cas.makespan_s, 3),
            "cascade_bounds": "|".join(map(str, cas.boundaries)),
        })
    print("\n== Cascade-guided pipeline partitioning (beyond paper) ==")
    cols = list(rows[0])
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[k]) for k in cols))
    return rows
