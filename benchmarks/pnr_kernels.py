"""Head-to-head PnR kernel benchmark: numpy vs jax, per stage, per app.

Times ``place()`` and ``route()`` separately for both kernel backends on
the benchmark apps (largest first in the claims: harris x4), so the
speedup is attributable to the stage, not the compile cache.  The jax
placer is timed twice — cold (first call pays the XLA compile) and warm
(the steady state ``compile_batch``/``explore_frontier`` fan-outs run in)
— and the quality contract is *asserted*, not just printed: best-replica
cost at or below the single-chain NumPy cost and wirelength at or below
A*'s on every app.

    PYTHONPATH=src python -m benchmarks.pnr_kernels [--fast]
        [--bench-out BENCH_pnr.json]

``benchmarks.run`` drives this as the ``pnr`` section and folds the rows
into its ``BENCH_pnr.json`` trajectory record.
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

from benchmarks._util import append_bench_record, print_csv

#: (app, unroll) pairs, smallest to largest; harris x4 is the headline
#: (the ISSUE's >= 5x place() criterion is checked against it).
BENCH_APPS = (("gaussian", 1), ("camera", 2), ("harris", 1),
              ("mttkrp", 2), ("harris", 4))
FAST_APPS = (("gaussian", 1), ("harris", 4))
SEED = 0


def _measure(nl, fabric, backend: str) -> Dict:
    from repro.core.place import PlaceParams, place
    from repro.core.route import RouteParams, route

    stats: dict = {}
    t0 = time.perf_counter()
    placement = place(nl, fabric, PlaceParams(seed=SEED, backend=backend),
                      stats=stats)
    t_place = time.perf_counter() - t0
    t0 = time.perf_counter()
    design = route(nl, placement, fabric, RouteParams(backend=backend))
    t_route = time.perf_counter() - t0
    return {"place_s": t_place, "route_s": t_route,
            "cost": stats["best_cost"],
            "wirelength": design.total_wirelength(),
            "replicas": stats.get("replicas")}


def run_all(fast: bool = False) -> Dict:
    from repro.core import ALL_APPS, devices
    from repro.core.interconnect import Fabric
    from repro.core.netlist import extract_netlist

    fabric = Fabric()
    rows: List[Dict] = []
    for app, mult in (FAST_APPS if fast else BENCH_APPS):
        nl = extract_netlist(ALL_APPS[app].build(mult))
        np_run = _measure(nl, fabric, "numpy")
        cold = _measure(nl, fabric, "jax")       # pays the XLA compile
        warm = _measure(nl, fabric, "jax")
        assert warm["cost"] <= np_run["cost"], (
            f"{app}x{mult}: jax best-replica cost {warm['cost']:.1f} above "
            f"single-chain numpy {np_run['cost']:.1f}")
        assert warm["wirelength"] <= np_run["wirelength"], (
            f"{app}x{mult}: jax wirelength {warm['wirelength']} above "
            f"A* {np_run['wirelength']}")
        rows.append({
            "app": f"{app}x{mult}",
            "nodes": len(nl.nodes),
            "replicas": warm["replicas"],
            "place_numpy_s": round(np_run["place_s"], 3),
            "place_jax_cold_s": round(cold["place_s"], 3),
            "place_jax_s": round(warm["place_s"], 3),
            "place_speedup": round(np_run["place_s"] / warm["place_s"], 2),
            "cost_numpy": round(np_run["cost"], 1),
            "cost_jax": round(warm["cost"], 1),
            "cost_ratio": round(warm["cost"] / np_run["cost"], 3),
            "route_numpy_s": round(np_run["route_s"], 3),
            "route_jax_s": round(warm["route_s"], 3),
            "wl_numpy": np_run["wirelength"],
            "wl_jax": warm["wirelength"],
        })
    print_csv(rows, "PnR kernels numpy-vs-jax (per-stage wall seconds)")
    largest = rows[-1]
    print(f"[pnr_kernels] {largest['app']}: place() "
          f"{largest['place_speedup']}x warm "
          f"(cost ratio {largest['cost_ratio']}) on "
          f"{len(devices())} device(s)")
    return {"devices": len(devices()), "apps": rows}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smallest + largest app only")
    ap.add_argument("--bench-out", default="BENCH_pnr.json",
                    help="trajectory file to append the stage table to")
    args = ap.parse_args()
    out = run_all(fast=args.fast)
    append_bench_record(args.bench_out, {"pnr_kernels": out})


if __name__ == "__main__":
    main()
