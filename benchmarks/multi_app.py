"""Multi-app fabric sharing benchmark: one packed fabric vs N separate ones.

Packs 2-4 app mixes (dense and sparse) into disjoint sub-fabric regions of
one fabric via ``compile_multi`` and compares against the status quo — each
app compiled alone on its own full fabric:

* shared-flush register savings (one hardened distribution network
  amortized across residents vs one per fabric, paper Section VI),
* fabric utilization of the packed design,
* min-frequency degradation each resident pays for its smaller region.

    PYTHONPATH=src python -m benchmarks.multi_app [--fast] [--mix NAME]
        [--backend auto|thread|process] [--workers N] [--moves N]
        [--bench-out BENCH_multi.json]

Each run appends a record per mix to ``BENCH_multi.json`` so the packing
trajectory is tracked across runs and PRs, like ``BENCH_pnr.json`` and
``BENCH_frontier.json``.
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

from benchmarks._util import append_bench_record, print_batch_stats, print_csv
from repro.core import (CascadeCompiler, CompileCache, MultiAppSpec,
                        PassConfig)
from repro.core.apps import ALL_APPS

MOVES = 100
FAST_MOVES = 40

#: 2-4 app mixes, dense and sparse mixed (names index ``ALL_APPS``).
MIXES: Dict[str, tuple] = {
    "dense2": ("unsharp", "camera"),
    "dense_sparse": ("unsharp", "vecadd"),
    "sparse2": ("vecadd", "ttv"),
    "quad": ("unsharp", "camera", "vecadd", "ttv"),
}
FAST_MIXES = ("dense_sparse",)


def run_mix(mix: str, moves: int = MOVES, backend: str = "auto",
            workers: Optional[int] = None,
            bench_out: Optional[str] = "BENCH_multi.json") -> Dict[str, object]:
    apps = [ALL_APPS[a] for a in MIXES[mix]]
    cfg = PassConfig.full(place_moves=moves)

    # each leg gets its own compiler with cold caches: the separate run
    # must not warm the packed run's stage tier (or the comparison would
    # be warm-vs-cold, overstating the packing advantage)
    def fresh():
        return CascadeCompiler(cache=CompileCache(),
                               stage_cache=CompileCache(),
                               batch_backend=backend, batch_workers=workers)

    # -- status quo: each app alone on its own full fabric ----------------
    sep_compiler = fresh()
    t0 = time.perf_counter()
    separate = sep_compiler.compile_batch([(a, cfg) for a in apps])
    t_separate = time.perf_counter() - t0
    print_batch_stats(sep_compiler, f"separate fabrics ({mix})")
    sep_freq = {r.app.name: r.sta.max_freq_mhz for r in separate}

    # -- packed: disjoint regions of one fabric, one shared flush ---------
    compiler = fresh()
    t0 = time.perf_counter()
    packed = compiler.compile_multi(MultiAppSpec.of(*apps, config=cfg))
    t_packed = time.perf_counter() - t0
    print_batch_stats(compiler, f"packed fabric ({mix})")
    # one source of truth for the N-separate-fabrics flush baseline
    sep_flush_regs = packed.flush.registers_separate

    rows: List[Dict] = []
    for r in packed.results:
        name = r.app.name
        region = packed.regions[name]
        degradation = 1.0 - r.sta.max_freq_mhz / sep_freq[name]
        rows.append({
            "app": name,
            "region": f"{region.rows}x{region.cols}@c{region.col0}",
            "freq_mhz": round(r.sta.max_freq_mhz, 1),
            "freq_separate_mhz": round(sep_freq[name], 1),
            "freq_degradation_pct": round(100 * degradation, 2),
            "unroll_copies": r.design.unroll_copies,
            "power_mw": round(r.power.power_mw, 1),
        })
    print_csv(rows, f"multi-app pack ({mix}): packed vs separate fabrics")

    s = packed.summary
    worst_degradation = max(r["freq_degradation_pct"] for r in rows)
    print(f"[multi] {mix}: {len(apps)} residents | "
          f"min freq {s['freq_mhz']:.1f} MHz (limited by "
          f"{s['freq_limited_by']}) | utilization {s['utilization']:.1%} | "
          f"flush registers {packed.flush.registers} shared vs "
          f"{sep_flush_regs} separate "
          f"(saves {packed.flush.register_savings}) | "
          f"worst min-freq degradation {worst_degradation:.1f}% | "
          f"packed {t_packed:.1f}s vs separate {t_separate:.1f}s")

    record = {
        "mix": mix, "apps": list(MIXES[mix]), "moves": moves,
        "backend": compiler.last_batch.get("backend"),
        "workers": compiler.last_batch.get("workers"),
        "residents": len(apps),
        "regions": {n: [r.row0, r.col0, r.rows, r.cols]
                    for n, r in packed.regions.items()},
        "fabric_freq_mhz": round(s["freq_mhz"], 2),
        "freq_limited_by": s["freq_limited_by"],
        "fabric_power_mw": round(s["power_mw"], 2),
        "fabric_edp_js": s["edp_js"],
        "utilization": s["utilization"],
        "flush_fanout": packed.flush.fanout,
        "flush_registers_shared": packed.flush.registers,
        "flush_registers_separate": sep_flush_regs,
        "flush_register_savings": packed.flush.register_savings,
        "worst_freq_degradation_pct": worst_degradation,
        "packed_seconds": round(t_packed, 3),
        "separate_seconds": round(t_separate, 3),
        "per_app": rows,
    }
    if bench_out:
        append_bench_record(bench_out, record)
    return record


def run_all(fast: bool = False, backend: str = "auto",
            workers: Optional[int] = None,
            bench_out: Optional[str] = "BENCH_multi.json") -> Dict:
    mixes = FAST_MIXES if fast else tuple(MIXES)
    return {m: run_mix(m, moves=FAST_MOVES if fast else MOVES,
                       backend=backend, workers=workers,
                       bench_out=bench_out)
            for m in mixes}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mix", default=None, choices=sorted(MIXES),
                    help="run a single mix (default: all, or the fast set)")
    ap.add_argument("--fast", action="store_true",
                    help="one 2-app mix at reduced SA moves (CI smoke)")
    ap.add_argument("--moves", type=int, default=None)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "thread", "process"))
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--bench-out", default="BENCH_multi.json")
    args = ap.parse_args()
    moves = args.moves or (FAST_MOVES if args.fast else MOVES)
    if args.mix:
        run_mix(args.mix, moves=moves, backend=args.backend,
                workers=args.workers, bench_out=args.bench_out)
    else:
        run_all(fast=args.fast, backend=args.backend, workers=args.workers,
                bench_out=args.bench_out)


if __name__ == "__main__":
    main()
