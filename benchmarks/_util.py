"""Shared helpers for the benchmark table modules."""

from __future__ import annotations

from typing import Dict, List


def print_csv(rows: List[Dict], name: str):
    """CSV-block printer used by every benchmark table module."""
    if not rows:
        return
    cols = list(rows[0])
    print(f"\n== {name} ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
