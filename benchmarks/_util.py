"""Shared helpers for the benchmark table modules."""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List


def print_csv(rows: List[Dict], name: str):
    """CSV-block printer used by every benchmark table module."""
    if not rows:
        return
    cols = list(rows[0])
    print(f"\n== {name} ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


def print_batch_stats(compiler, label: str):
    """One-line report of the last ``compile_batch``: backend, workers,
    cache-tier hit split — the PnR-wall-clock story of the table."""
    b = compiler.last_batch
    if not b:
        return
    print(f"[batch] {label}: backend={b.get('backend')} "
          f"workers={b.get('workers')} jobs={b.get('jobs')} "
          f"unique={b.get('unique')} cache_hits={b.get('cache_hits')} "
          f"compiled={b.get('compiled')} wall={b.get('wall_seconds')}s")


def apply_pnr_backend(compiler, backend):
    """Driver-side copy of ``--backend-pnr`` / ``CASCADE_PNR_BACKEND`` into
    every job's ``PassConfig.pnr_backend`` (the compiler never reads the
    env var itself, keeping cache keys faithful).  Wraps the compiler
    instance's ``compile``/``compile_batch`` so the table modules stay
    oblivious; ``backend=None`` is a no-op."""
    if not backend:
        return compiler
    from dataclasses import replace

    orig_compile = compiler.compile
    orig_batch = compiler.compile_batch

    def _compile(app, config, **kw):
        return orig_compile(app, replace(config, pnr_backend=backend), **kw)

    def _batch(jobs, **kw):
        return orig_batch([(a, replace(c, pnr_backend=backend))
                           for a, c in jobs], **kw)

    compiler.compile = _compile
    compiler.compile_batch = _batch
    return compiler


def append_bench_record(path: str, record: Dict) -> None:
    """Append one trajectory record to the ``BENCH_pnr.json`` file.

    The file is a JSON list so successive runs (and successive PRs' CI
    jobs) accumulate a wall-clock trajectory; a corrupt or legacy file is
    reset rather than crashing the benchmark run.
    """
    record = {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"), **record}
    history: List[Dict] = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                loaded = json.load(f)
            if isinstance(loaded, list):
                history = loaded
        except (OSError, ValueError):
            pass
    history.append(record)
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")
    os.replace(tmp, path)
    print(f"[bench] appended PnR trajectory record -> {path} "
          f"({len(history)} records)")
