"""Simulator backend head-to-head + trace-driven throughput replay.

Times ``simulate`` / ``simulate_sparse`` for the interpreter, numpy, and
jax backends on the benchmark apps, asserting two contracts from the
vectorized-simulator work:

* **bit identity** — every backend produces byte-equal output streams on
  every app (16-bit random input streams);
* **speed** — the warm jax backend is >= 10x faster than the interpreter
  on a 4096-cycle harris run (the jit is lru-cached on program shape, so
  the cold call pays XLA compile once and the steady state is what the
  oracle-check and traffic workloads see).

On top, replays periodic and Poisson arrival traces against a two-app
``compile_multi`` pack (``repro.core.traffic``) and reports per-app fill
latency, steady-state/achieved throughput, and downtime fractions.

    PYTHONPATH=src python -m benchmarks.sim_throughput [--fast]
        [--bench-out BENCH_sim.json]

``benchmarks.run`` drives this as the ``sim`` section and folds the rows
into its ``BENCH_sim.json`` trajectory record.
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from benchmarks._util import append_bench_record, print_csv

SEED = 0
HARRIS_CYCLES = 4096            # the >= 10x assertion's workload
DENSE_CYCLES_FULL = 1024        # the non-headline dense apps
SPARSE_TOKENS = 64


def _dense_inputs(g, cycles: int, seed: int = SEED) -> Dict[str, list]:
    rng = np.random.default_rng(seed)
    return {n: rng.integers(0, 0x10000, size=cycles).tolist()
            for n, nd in g.nodes.items() if nd.kind == "input"}


def _time(fn, repeat: int = 1) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def dense_rows(fast: bool = False) -> List[Dict]:
    from repro.core import DENSE_APPS, simulate

    apps = ["gaussian", "harris"] if fast else list(DENSE_APPS)
    if "harris" not in apps:
        apps.append("harris")
    rows = []
    for name in apps:
        g = DENSE_APPS[name].build(1)
        cycles = HARRIS_CYCLES if name == "harris" else \
            (HARRIS_CYCLES if fast else DENSE_CYCLES_FULL)
        ins = _dense_inputs(g, cycles)
        ref = {}
        t_interp = _time(lambda: ref.update(simulate(g, ins, cycles)))
        out_np = {}
        t_np = _time(lambda: out_np.update(
            simulate(g, ins, cycles, backend="numpy")))
        t_jax_cold = _time(lambda: simulate(g, ins, cycles, backend="jax"))
        out_jax = {}
        t_jax = _time(lambda: out_jax.update(
            simulate(g, ins, cycles, backend="jax")), repeat=3)
        assert out_np == ref, f"{name}: numpy dense streams diverge"
        assert out_jax == ref, f"{name}: jax dense streams diverge"
        row = {
            "app": name, "nodes": len(g.nodes), "cycles": cycles,
            "interp_s": round(t_interp, 4),
            "numpy_s": round(t_np, 4),
            "jax_cold_s": round(t_jax_cold, 4),
            "jax_s": round(t_jax, 4),
            "interp_cps": round(cycles / t_interp),
            "numpy_cps": round(cycles / t_np),
            "jax_cps": round(cycles / t_jax),
            "numpy_speedup": round(t_interp / t_np, 2),
            "jax_speedup": round(t_interp / t_jax, 2),
        }
        if name == "harris" and cycles == HARRIS_CYCLES:
            assert row["jax_speedup"] >= 10, (
                f"harris {HARRIS_CYCLES}-cycle warm jax speedup "
                f"{row['jax_speedup']}x below the 10x bar")
        rows.append(row)
    return rows


def sparse_rows(fast: bool = False) -> List[Dict]:
    from repro.core import SPARSE_APPS, simulate_sparse

    apps = ["vecadd", "mttkrp"] if fast else list(SPARSE_APPS)
    rng = np.random.default_rng(SEED)
    rows = []
    for name in apps:
        g = SPARSE_APPS[name].build(1)
        ins = {n: rng.integers(0, 0x10000, size=SPARSE_TOKENS).tolist()
               for n, nd in g.nodes.items() if nd.kind == "input"}
        # the synchronous fire-vector advances one hop per round — bound
        # generously but identically for all backends
        max_cycles = SPARSE_TOKENS * 40
        ref = {}
        t_interp = _time(lambda: ref.update(
            simulate_sparse(g, ins, max_cycles)))
        out_np = {}
        t_np = _time(lambda: out_np.update(
            simulate_sparse(g, ins, max_cycles, backend="numpy")))
        _time(lambda: simulate_sparse(g, ins, max_cycles, backend="jax"))
        out_jax = {}
        t_jax = _time(lambda: out_jax.update(
            simulate_sparse(g, ins, max_cycles, backend="jax")), repeat=3)
        assert out_np == ref, f"{name}: numpy sparse streams diverge"
        assert out_jax == ref, f"{name}: jax sparse streams diverge"
        rows.append({
            "app": name, "nodes": len(g.nodes), "tokens": SPARSE_TOKENS,
            "interp_s": round(t_interp, 4),
            "numpy_s": round(t_np, 4),
            "jax_s": round(t_jax, 4),
            "numpy_speedup": round(t_interp / t_np, 2),
            "jax_speedup": round(t_interp / t_jax, 2),
        })
    return rows


def traffic_rows(fast: bool = False) -> Dict:
    from repro.core import (ALL_APPS, CascadeCompiler, CompileCache,
                            MultiAppSpec, PassConfig, periodic_trace,
                            poisson_trace, replay)

    c = CascadeCompiler(cache=CompileCache(), stage_cache=CompileCache())
    cfg = PassConfig.full(place_moves=20 if fast else 60)
    pack = c.compile_multi(MultiAppSpec.of(
        ALL_APPS["unsharp"], ALL_APPS["vecadd"], config=cfg))
    n_req = 50 if fast else 500
    reports = {}
    for trace in (periodic_trace(["unsharp", "vecadd"], period=2000,
                                 n_requests=n_req, phase=37),
                  poisson_trace(["unsharp", "vecadd"], mean_gap=2000,
                                n_requests=n_req, seed=SEED)):
        rep = replay(pack, trace, iterations=1024)
        reports[trace.name] = {
            "summary": rep.summary(),
            "per_app": rep.rows(),
        }
    return reports


def run_all(fast: bool = False) -> Dict:
    dense = dense_rows(fast=fast)
    print_csv(dense, "simulate() interpreter vs numpy vs jax (cycles/sec)")
    sparse = sparse_rows(fast=fast)
    print_csv(sparse, "simulate_sparse() interpreter vs numpy vs jax")
    traffic = traffic_rows(fast=fast)
    for tname, rep in traffic.items():
        print_csv(rep["per_app"], f"trace replay: {tname}")
        print(f"[sim_throughput] {tname}: {rep['summary']}")
    harris = next(r for r in dense
                  if r["app"] == "harris" and r["cycles"] == HARRIS_CYCLES)
    print(f"[sim_throughput] harris {HARRIS_CYCLES} cycles: interpreter "
          f"{harris['interp_cps']} c/s, numpy {harris['numpy_cps']} c/s, "
          f"jax {harris['jax_cps']} c/s "
          f"({harris['jax_speedup']}x, bar >= 10x)")
    return {"dense": dense, "sparse": sparse, "traffic": traffic}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--bench-out", default="BENCH_sim.json")
    args = ap.parse_args()
    t0 = time.time()
    results = run_all(fast=args.fast)
    append_bench_record(args.bench_out, {
        "fast": args.fast,
        "total_seconds": round(time.time() - t0, 2),
        **results,
    })


if __name__ == "__main__":
    main()
