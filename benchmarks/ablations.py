"""Paper-adjacent ablations: the two Cascade hyperparameters with a
quality/resource trade-off.

* placement alpha (Eq. 1 criticality exponent) sweep — Section V-C
* post-PnR register budget sweep — Section V-D ("number of registers added
  vs critical path" trade-off the paper describes for broadcast/post-PnR)
"""

from __future__ import annotations

from typing import Dict, List

from repro.core.apps import ALL_APPS
from repro.core.compiler import CascadeCompiler, PassConfig

MOVES = 100


def alpha_sweep(app: str = "harris") -> List[Dict]:
    c = CascadeCompiler()
    rows = []
    for alpha in (1.0, 1.3, 1.6, 2.0, 2.5):
        cfg = PassConfig.full(place_moves=MOVES, placement_alpha=alpha,
                              seed=1)
        r = c.compile(ALL_APPS[app], cfg)
        rows.append({"app": app, "alpha": alpha,
                     "critical_path_ns": round(r.sta.critical_path_ns, 3),
                     "freq_mhz": round(r.sta.max_freq_mhz, 1),
                     "registers": r.design.physical_register_count()})
    print("\n== ablation: placement alpha (Eq. 1) ==")
    cols = list(rows[0])
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[k]) for k in cols))
    return rows


def budget_sweep(app: str = "unsharp") -> List[Dict]:
    c = CascadeCompiler()
    rows = []
    for budget in (0, 8, 32, 128, 512):
        cfg = PassConfig.full(place_moves=MOVES, post_pnr_budget=budget,
                              seed=1)
        r = c.compile(ALL_APPS[app], cfg)
        rows.append({"app": app, "register_budget": budget,
                     "critical_path_ns": round(r.sta.critical_path_ns, 3),
                     "freq_mhz": round(r.sta.max_freq_mhz, 1),
                     "regs_added": (r.post_pnr.registers_added
                                    if r.post_pnr else 0)})
    print("\n== ablation: post-PnR register budget ==")
    cols = list(rows[0])
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[k]) for k in cols))
    return rows


def run_all() -> Dict[str, List[Dict]]:
    return {"alpha": alpha_sweep(), "budget": budget_sweep()}
