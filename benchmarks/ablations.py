"""Paper-adjacent ablations: the two Cascade hyperparameters with a
quality/resource trade-off.

* placement alpha (Eq. 1 criticality exponent) sweep — Section V-C
* post-PnR register budget sweep — Section V-D ("number of registers added
  vs critical path" trade-off the paper describes for broadcast/post-PnR)

Both sweeps batch-compile their whole config grid concurrently through
``compile_batch`` (the points are independent).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from benchmarks._util import print_batch_stats, print_csv
from repro.core.apps import ALL_APPS
from repro.core.compiler import CascadeCompiler, PassConfig

MOVES = 100
FAST_MOVES = 40

ALPHAS = (1.0, 1.3, 1.6, 2.0, 2.5)
FAST_ALPHAS = (1.0, 1.6, 2.5)
BUDGETS = (0, 8, 32, 128, 512)
FAST_BUDGETS = (0, 32, 512)


def alpha_sweep(app: str = "harris", compiler: Optional[CascadeCompiler] = None,
                moves: int = MOVES,
                alphas: Sequence[float] = ALPHAS) -> List[Dict]:
    c = compiler or CascadeCompiler()
    jobs = [(ALL_APPS[app], PassConfig.full(place_moves=moves,
                                            placement_alpha=alpha, seed=1))
            for alpha in alphas]
    rows = []
    for alpha, r in zip(alphas, c.compile_batch(jobs)):
        rows.append({"app": app, "alpha": alpha,
                     "critical_path_ns": round(r.sta.critical_path_ns, 3),
                     "freq_mhz": round(r.sta.max_freq_mhz, 1),
                     "registers": r.design.physical_register_count()})
    print_csv(rows, "ablation: placement alpha (Eq. 1)")
    return rows


def budget_sweep(app: str = "unsharp",
                 compiler: Optional[CascadeCompiler] = None,
                 moves: int = MOVES,
                 budgets: Sequence[int] = BUDGETS) -> List[Dict]:
    c = compiler or CascadeCompiler()
    jobs = [(ALL_APPS[app], PassConfig.full(place_moves=moves,
                                            post_pnr_budget=budget, seed=1))
            for budget in budgets]
    rows = []
    for budget, r in zip(budgets, c.compile_batch(jobs)):
        rows.append({"app": app, "register_budget": budget,
                     "critical_path_ns": round(r.sta.critical_path_ns, 3),
                     "freq_mhz": round(r.sta.max_freq_mhz, 1),
                     "regs_added": (r.post_pnr.registers_added
                                    if r.post_pnr else 0)})
    print_csv(rows, "ablation: post-PnR register budget")
    return rows


def run_all(fast: bool = False, backend: str = "auto",
            workers: Optional[int] = None) -> Dict[str, List[Dict]]:
    c = CascadeCompiler(batch_backend=backend, batch_workers=workers)
    moves = FAST_MOVES if fast else MOVES
    out = {
        "alpha": alpha_sweep(compiler=c, moves=moves,
                             alphas=FAST_ALPHAS if fast else ALPHAS),
        "budget": budget_sweep(compiler=c, moves=moves,
                               budgets=FAST_BUDGETS if fast else BUDGETS),
    }
    print_batch_stats(c, "ablations")
    return out
