"""Paper-adjacent ablations: the Cascade hyperparameters with a
quality/resource trade-off, plus the beyond-paper power-cap sweep.

* placement alpha (Eq. 1 criticality exponent) sweep — Section V-C
* post-PnR register budget sweep — Section V-D ("number of registers added
  vs critical path" trade-off the paper describes for broadcast/post-PnR)
* power-cap sweep — the Capstone-style ``"power_capped"`` schedule at a
  ladder of power budgets (fractions of the uncapped power), tabulating
  the Pareto point each cap reaches: cap -> freq / power / EDP / registers

All sweeps batch-compile their whole config grid concurrently through
``compile_batch`` (the points are independent).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from benchmarks._util import (apply_pnr_backend, print_batch_stats,
                              print_csv)
from repro.core.apps import ALL_APPS
from repro.core.compiler import CascadeCompiler, PassConfig

MOVES = 100
FAST_MOVES = 40

ALPHAS = (1.0, 1.3, 1.6, 2.0, 2.5)
FAST_ALPHAS = (1.0, 1.6, 2.5)
BUDGETS = (0, 8, 32, 128, 512)
FAST_BUDGETS = (0, 32, 512)
CAP_FRACTIONS = (0.75, 0.85, 0.95, 1.0)
FAST_CAP_FRACTIONS = (0.85, 1.0)


def alpha_sweep(app: str = "harris", compiler: Optional[CascadeCompiler] = None,
                moves: int = MOVES,
                alphas: Sequence[float] = ALPHAS) -> List[Dict]:
    c = compiler or CascadeCompiler()
    jobs = [(ALL_APPS[app], PassConfig.full(place_moves=moves,
                                            placement_alpha=alpha, seed=1))
            for alpha in alphas]
    rows = []
    for alpha, r in zip(alphas, c.compile_batch(jobs)):
        rows.append({"app": app, "alpha": alpha,
                     "critical_path_ns": round(r.sta.critical_path_ns, 3),
                     "freq_mhz": round(r.sta.max_freq_mhz, 1),
                     "registers": r.design.physical_register_count()})
    print_csv(rows, "ablation: placement alpha (Eq. 1)")
    return rows


def budget_sweep(app: str = "unsharp",
                 compiler: Optional[CascadeCompiler] = None,
                 moves: int = MOVES,
                 budgets: Sequence[int] = BUDGETS) -> List[Dict]:
    c = compiler or CascadeCompiler()
    jobs = [(ALL_APPS[app], PassConfig.full(place_moves=moves,
                                            post_pnr_budget=budget, seed=1))
            for budget in budgets]
    rows = []
    for budget, r in zip(budgets, c.compile_batch(jobs)):
        rows.append({"app": app, "register_budget": budget,
                     "critical_path_ns": round(r.sta.critical_path_ns, 3),
                     "freq_mhz": round(r.sta.max_freq_mhz, 1),
                     "regs_added": (r.post_pnr.registers_added
                                    if r.post_pnr else 0)})
    print_csv(rows, "ablation: post-PnR register budget")
    return rows


def cap_sweep(app: str = "unsharp",
              compiler: Optional[CascadeCompiler] = None,
              moves: int = MOVES,
              fractions: Sequence[float] = CAP_FRACTIONS) -> List[Dict]:
    """Power-cap ladder: compile the app uncapped to find its natural power,
    then re-compile under caps at ``fractions`` of it.  Each row is the
    Pareto point the controller reached — by construction the reported
    power never exceeds the cap."""
    c = compiler or CascadeCompiler()
    base = c.compile_batch(
        [(ALL_APPS[app], PassConfig.power_capped(None, place_moves=moves,
                                                 seed=1))])[0]
    # compile with the exact caps (rounding could push a cap below the
    # uncapped power and stop that sweep point a round early); round only
    # the table label
    caps = [base.power.power_mw * f for f in fractions]
    jobs = [(ALL_APPS[app], PassConfig.power_capped(cap, place_moves=moves,
                                                    seed=1))
            for cap in caps]

    def row(label, r):
        return {"app": app, "cap_mw": label,
                "power_mw": round(r.power.power_mw, 1),
                "freq_mhz": round(r.sta.max_freq_mhz, 1),
                "edp_ujs": round(r.power.edp_js * 1e6, 4),
                "regs_added": (r.power_cap.final.registers_added
                               if r.power_cap else 0),
                "stop": r.power_cap.stop_reason if r.power_cap else ""}

    rows = [row("uncapped", base)]
    for cap, r in zip(caps, c.compile_batch(jobs)):
        # an infeasible cap (below even the un-pipelined design's power) is
        # a legitimate sweep outcome: tabulate it, don't die on it
        assert not r.power_cap.feasible or r.power.power_mw <= cap + 1e-9, \
            f"{app}: reported {r.power.power_mw} mW exceeds cap {cap} mW"
        rows.append(row(round(cap, 2), r))
    print_csv(rows, "ablation: power cap (Capstone-style, beyond paper)")
    return rows


def run_all(fast: bool = False, backend: str = "auto",
            workers: Optional[int] = None,
            backend_pnr: Optional[str] = None) -> Dict[str, List[Dict]]:
    c = apply_pnr_backend(
        CascadeCompiler(batch_backend=backend, batch_workers=workers),
        backend_pnr)
    moves = FAST_MOVES if fast else MOVES
    out = {
        "alpha": alpha_sweep(compiler=c, moves=moves,
                             alphas=FAST_ALPHAS if fast else ALPHAS),
        "budget": budget_sweep(compiler=c, moves=moves,
                               budgets=FAST_BUDGETS if fast else BUDGETS),
        "power_cap": cap_sweep(compiler=c, moves=moves,
                               fractions=(FAST_CAP_FRACTIONS if fast
                                          else CAP_FRACTIONS)),
    }
    print_batch_stats(c, "ablations")
    return out
