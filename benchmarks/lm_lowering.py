"""Cascade applied to the assigned LM architectures (arch bridge bench).

For each of the 10 assigned architectures, lower its block-compute tile to a
CGRA DFG (repro.core.lmmap) and compile it unpipelined vs fully pipelined —
the paper's dense bands should hold on LM compute, and the MoE lowering
exercises the sparse (ready-valid FIFO) path.
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs import ARCHS
from repro.core.compiler import CascadeCompiler, PassConfig
from repro.core.lmmap import lower_block

MOVES = 100


def run_all() -> List[Dict]:
    c = CascadeCompiler()
    rows = []
    for name, cfg in ARCHS.items():
        spec = lower_block(cfg)
        r0 = c.compile(spec, PassConfig.unpipelined(place_moves=MOVES))
        r1 = c.compile(spec, PassConfig.full(place_moves=MOVES))
        rows.append({
            "arch": name,
            "family": cfg.family,
            "sparse_path": int(spec.sparse),
            "unpip_mhz": round(r0.sta.max_freq_mhz, 0),
            "pip_mhz": round(r1.sta.max_freq_mhz, 0),
            "cp_ratio": round(r0.sta.critical_path_ns /
                              r1.sta.critical_path_ns, 2),
            "edp_ratio": round(r0.power.edp_js / r1.power.edp_js, 2),
        })
    print("\n== LM block -> CGRA lowering (Cascade on assigned archs) ==")
    cols = list(rows[0])
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[k]) for k in cols))
    return rows
