"""Cascade applied to the assigned LM architectures (arch bridge bench).

For each of the 10 assigned architectures, lower its block-compute tile to a
CGRA DFG (repro.core.lmmap) and compile it unpipelined vs fully pipelined —
the paper's dense bands should hold on LM compute, and the MoE lowering
exercises the sparse (ready-valid FIFO) path.  The 2x10 grid of compiles is
independent, so it goes through ``compile_batch`` in one shot.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from benchmarks._util import (apply_pnr_backend, print_batch_stats,
                              print_csv)
from repro.configs import ARCHS
from repro.core.compiler import CascadeCompiler, PassConfig
from repro.core.lmmap import lower_block

MOVES = 100
FAST_MOVES = 40


def run_all(fast: bool = False, backend: str = "auto",
            workers: Optional[int] = None,
            backend_pnr: Optional[str] = None) -> List[Dict]:
    moves = FAST_MOVES if fast else MOVES
    c = apply_pnr_backend(
        CascadeCompiler(batch_backend=backend, batch_workers=workers),
        backend_pnr)
    archs = list(ARCHS.items())
    specs = {name: lower_block(cfg) for name, cfg in archs}
    jobs = [(specs[name], cfg_pass)
            for name, _ in archs
            for cfg_pass in (PassConfig.unpipelined(place_moves=moves),
                             PassConfig.full(place_moves=moves))]
    results = c.compile_batch(jobs)
    rows = []
    for i, (name, cfg) in enumerate(archs):
        r0, r1 = results[2 * i], results[2 * i + 1]
        rows.append({
            "arch": name,
            "family": cfg.family,
            "sparse_path": int(specs[name].sparse),
            "unpip_mhz": round(r0.sta.max_freq_mhz, 0),
            "pip_mhz": round(r1.sta.max_freq_mhz, 0),
            "cp_ratio": round(r0.sta.critical_path_ns /
                              r1.sta.critical_path_ns, 2),
            "edp_ratio": round(r0.power.edp_js / r1.power.edp_js, 2),
        })
    print_csv(rows, "LM block -> CGRA lowering (Cascade on assigned archs)")
    print_batch_stats(c, "lm_lowering")
    return rows
