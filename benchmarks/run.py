"""Benchmark driver: one module per paper table/figure + the roofline and
beyond-paper benches.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Prints CSV blocks per artifact and a final band-check against the paper's
headline claims.
"""

from __future__ import annotations

import argparse
import sys
import time


def _band(name: str, lo, hi, values, allow_slack=0.0) -> str:
    vmin, vmax = min(values), max(values)
    ok = vmin >= lo * (1 - allow_slack)
    return (f"  {name:34s} paper {lo}-{hi}x   ours {vmin:.1f}-{vmax:.1f}x   "
            f"{'OK' if ok else 'BELOW BAND'}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="cascade|lm|roofline|pipeline|ablations")
    ap.add_argument("--fast", action="store_true",
                    help="reduced SA move counts / sweep grids for a quick "
                         "smoke run (tables keep their shape, lose accuracy)")
    args = ap.parse_args()
    t0 = time.time()
    results = {}

    if args.only in (None, "cascade"):
        from benchmarks import cascade_tables
        results.update(cascade_tables.run_all(fast=args.fast))

    if args.only in (None, "lm"):
        from benchmarks import lm_lowering
        results["lm_lowering"] = lm_lowering.run_all(fast=args.fast)

    if args.only in (None, "pipeline"):
        from benchmarks import pipeline_partition
        results["pipeline"] = pipeline_partition.run_all()

    if args.only in (None, "ablations"):
        from benchmarks import ablations
        results["ablations"] = ablations.run_all(fast=args.fast)

    if args.only in (None, "roofline"):
        from benchmarks import roofline
        results["roofline"] = roofline.run_all()

    # ----- headline band checks (paper abstract) -------------------------
    if "dense_table" in results:
        print("\n== Paper band check ==")
        dt = results["dense_table"]
        print(_band("dense critical-path ratio", 7, 34,
                    [r["cp_ratio"] for r in dt], allow_slack=0.05))
        print(_band("dense EDP ratio", 7, 190,
                    [r["edp_ratio"] for r in dt], allow_slack=0.05))
        st = results["sparse_table"]
        print(_band("sparse critical-path ratio", 2, 4.4,
                    [r["cp_ratio"] for r in st], allow_slack=0.1))
        print(_band("sparse EDP ratio", 1.5, 4.2,
                    [r["edp_ratio"] for r in st], allow_slack=0.1))
        fh = results["flush_hardening"]
        drops = [r["runtime_drop_pct"] for r in fh]
        print(f"  {'flush hardening runtime drop':34s} paper 31-56%   "
              f"ours {min(drops):.0f}-{max(drops):.0f}%")
        sa = [r for r in results["sta_accuracy"] if r["app"] == "MEAN>500MHz"]
        if sa:
            print(f"  {'STA err above 500 MHz':34s} paper ~13%     "
                  f"ours {sa[0]['err_pct']}%")

    print(f"\n[benchmarks] total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
