"""Benchmark driver: one module per paper table/figure + the roofline and
beyond-paper benches.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]
        [--backend auto|thread|process] [--backend-pnr scalar|numpy|jax]
        [--workers N] [--no-disk-cache] [--bench-out PATH]

``--backend-pnr`` (or ``CASCADE_PNR_BACKEND``) selects the place/route
kernel backend the compile-heavy sections build their ``PassConfig`` with;
the ``pnr`` section always benchmarks numpy vs jax head-to-head and folds
the per-stage timing table into the trajectory record.

Prints CSV blocks per artifact and a final band-check against the paper's
headline claims.  Each run appends a record to ``BENCH_pnr.json`` —
backend, worker count, per-section wall seconds, cache-tier hit rates — so
the PnR wall-clock trajectory is tracked across runs (and across PRs via
the CI artifact).  The disk compile cache is attached by default, so a
second benchmark process skips every recompile; ``--no-disk-cache`` forces
cold compiles.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def _band(name: str, lo, hi, values, allow_slack=0.0) -> str:
    vmin, vmax = min(values), max(values)
    ok = vmin >= lo * (1 - allow_slack)
    return (f"  {name:34s} paper {lo}-{hi}x   ours {vmin:.1f}-{vmax:.1f}x   "
            f"{'OK' if ok else 'BELOW BAND'}")


def main() -> None:
    from repro.core import (BATCH_BACKENDS, DEFAULT_CACHE,
                            DEFAULT_STAGE_CACHE, PNR_BACKENDS,
                            attach_disk_cache, attach_stage_disk_cache,
                            pnr_backend, worker_count)

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="cascade|lm|roofline|pipeline|ablations|frontier|"
                         "multi|pnr|sta|sim|serve|cf")
    ap.add_argument("--fast", action="store_true",
                    help="reduced SA move counts / sweep grids for a quick "
                         "smoke run (tables keep their shape, lose accuracy)")
    ap.add_argument("--backend", default="auto", choices=BATCH_BACKENDS,
                    help="compile_batch backend (process = multi-core PnR)")
    ap.add_argument("--workers", type=int, default=None,
                    help="batch worker count (default: CASCADE_WORKERS or "
                         "min(8, cpu count))")
    ap.add_argument("--no-disk-cache", action="store_true",
                    help="skip the disk compile-cache tier (force cold "
                         "compiles)")
    ap.add_argument("--bench-out", default="BENCH_pnr.json",
                    help="PnR wall-clock trajectory file to append to")
    ap.add_argument("--backend-pnr", default=None, choices=PNR_BACKENDS,
                    help="place/route kernel backend for the compile "
                         "sections (cascade/lm/ablations); default: "
                         "CASCADE_PNR_BACKEND or each config's own "
                         "(numpy).  The pnr section always runs both "
                         "kernels head-to-head.")
    args = ap.parse_args()
    backend_pnr = args.backend_pnr or (
        pnr_backend() if os.environ.get("CASCADE_PNR_BACKEND") else None)

    if args.no_disk_cache:
        # also detach tiers CASCADE_DISK_CACHE=1 attached at import —
        # "--no-disk-cache" must actually mean cold compiles
        DEFAULT_CACHE.disk = None
        DEFAULT_STAGE_CACHE.disk = None
    else:
        disk = attach_disk_cache()
        stages = attach_stage_disk_cache()
        print(f"[bench] disk compile cache: {disk.dir}")
        print(f"[bench] disk stage-artifact cache: {stages.dir}")
    t0 = time.time()
    results = {}
    sections = {}

    def section(name, fn):
        s0 = time.time()
        out = fn()
        sections[name] = round(time.time() - s0, 2)
        return out

    if args.only in (None, "cascade"):
        from benchmarks import cascade_tables
        results.update(section("cascade", lambda: cascade_tables.run_all(
            fast=args.fast, backend=args.backend, workers=args.workers,
            backend_pnr=backend_pnr)))

    if args.only in (None, "lm"):
        from benchmarks import lm_lowering
        results["lm_lowering"] = section("lm", lambda: lm_lowering.run_all(
            fast=args.fast, backend=args.backend, workers=args.workers,
            backend_pnr=backend_pnr))

    if args.only in (None, "pipeline"):
        from benchmarks import pipeline_partition
        results["pipeline"] = section("pipeline",
                                      pipeline_partition.run_all)

    if args.only in (None, "ablations"):
        from benchmarks import ablations
        results["ablations"] = section("ablations", lambda: ablations.run_all(
            fast=args.fast, backend=args.backend, workers=args.workers,
            backend_pnr=backend_pnr))

    if args.only in (None, "frontier"):
        from benchmarks import frontier
        results["frontier"] = section("frontier", lambda: frontier.run_all(
            fast=args.fast, backend=args.backend, workers=args.workers))

    if args.only in (None, "multi"):
        from benchmarks import multi_app
        results["multi"] = section("multi", lambda: multi_app.run_all(
            fast=args.fast, backend=args.backend, workers=args.workers))

    if args.only in (None, "roofline"):
        from benchmarks import roofline
        results["roofline"] = section("roofline", roofline.run_all)

    if args.only in (None, "pnr"):
        from benchmarks import pnr_kernels
        results["pnr_kernels"] = section("pnr", lambda: pnr_kernels.run_all(
            fast=args.fast))

    if args.only in (None, "sta"):
        from benchmarks import sta_pipeline
        results["sta"] = section("sta", lambda: sta_pipeline.run_all(
            fast=args.fast))

    if args.only in (None, "sim"):
        from benchmarks import sim_throughput
        results["sim"] = section("sim", lambda: sim_throughput.run_all(
            fast=args.fast))

    if args.only in (None, "serve"):
        from benchmarks import serve_online
        results["serve"] = section("serve", lambda: serve_online.run_all(
            fast=args.fast))

    if args.only in (None, "cf"):
        from benchmarks import control_flow
        results["cf"] = section("cf", lambda: control_flow.run_all(
            fast=args.fast, backend=args.backend, workers=args.workers,
            backend_pnr=backend_pnr, bench_out="BENCH_cf.json"))

    # ----- headline band checks (paper abstract) -------------------------
    if "dense_table" in results:
        print("\n== Paper band check ==")
        dt = results["dense_table"]
        print(_band("dense critical-path ratio", 7, 34,
                    [r["cp_ratio"] for r in dt], allow_slack=0.05))
        print(_band("dense EDP ratio", 7, 190,
                    [r["edp_ratio"] for r in dt], allow_slack=0.05))
        st = results["sparse_table"]
        print(_band("sparse critical-path ratio", 2, 4.4,
                    [r["cp_ratio"] for r in st], allow_slack=0.1))
        print(_band("sparse EDP ratio", 1.5, 4.2,
                    [r["edp_ratio"] for r in st], allow_slack=0.1))
        fh = results["flush_hardening"]
        drops = [r["runtime_drop_pct"] for r in fh]
        print(f"  {'flush hardening runtime drop':34s} paper 31-56%   "
              f"ours {min(drops):.0f}-{max(drops):.0f}%")
        sa = [r for r in results["sta_accuracy"] if r["app"] == "MEAN>500MHz"]
        if sa:
            print(f"  {'STA err above 500 MHz':34s} paper ~13%     "
                  f"ours {sa[0]['err_pct']}%")

    total = time.time() - t0
    print(f"\n[benchmarks] total {total:.1f}s")

    from benchmarks._util import append_bench_record
    record = {
        "fast": args.fast,
        "only": args.only,
        "backend": args.backend,
        "backend_pnr": backend_pnr,
        "workers": args.workers or worker_count(),
        "disk_cache": not args.no_disk_cache,
        "cpu_count": os.cpu_count(),
        "python": sys.version.split()[0],
        "total_seconds": round(total, 2),
        "sections": sections,
        "cache": DEFAULT_CACHE.stats(),
    }
    # the power-cap Pareto ladder rides along in the trajectory, so cap
    # sweeps are comparable across runs/PRs just like wall-clock
    cap_rows = (results.get("ablations") or {}).get("power_cap")
    if cap_rows:
        record["power_cap_sweep"] = cap_rows
    # per-stage place/route kernel timings ride along so the speedup
    # claim is attributable to the stage, not the cache
    if results.get("pnr_kernels"):
        record["pnr_kernels"] = results["pnr_kernels"]
    # the vectorized-STA pipelining-loop speedups (and the explore
    # end-to-end number) ride along so the >=5x incremental-loop claim is
    # tracked per run
    if results.get("sta"):
        record["sta"] = results["sta"]
    # simulator backend head-to-head + traffic replay rows ride along so
    # the >=10x jax claim and the throughput objective are tracked per run
    if results.get("sim"):
        record["sim"] = results["sim"]
    # online-vs-static serving headline rides along so the scheduler's
    # win margin on fragmentation-heavy traces is tracked per run
    # the predicated-app freq/EDP rows ride along so control-flow apps'
    # parity with the straight-line suite is tracked per run
    if results.get("cf"):
        record["cf"] = results["cf"]["compile"]
    if results.get("serve"):
        record["serve"] = {
            name: {"objective_gain": r["objective_gain"],
                   "rejection_delta": r["rejection_delta"],
                   "online_wins": r["online_wins"]}
            for name, r in results["serve"].items()}
    append_bench_record(args.bench_out, record)


if __name__ == "__main__":
    main()
