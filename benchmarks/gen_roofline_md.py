"""Regenerate the EXPERIMENTS.md §Roofline markdown table from the dry-run
artifacts (run after a fresh `dryrun --all` sweep)."""

import glob
import json


def main():
    rows = []
    for f in sorted(glob.glob("experiments/dryrun/*_16x16.json")):
        d = json.load(open(f))
        if d.get("mesh") != "16x16":
            continue
        if "skipped" in d:
            rows.append(f"| {d['arch']} | {d['shape']} | — | — | — | SKIP "
                        f"| — | — |")
            continue
        r = d["roofline"]
        args_gb = d.get("memory", {}).get(
            "args_bytes_exact",
            d.get("memory", {}).get("argument_size_in_bytes", 0)) / 1e9
        u = d.get("useful_flop_ratio", "—")
        fr = d.get("roofline_fraction", "—")
        rows.append(
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.4g} "
            f"| {r['memory_s']:.4g} | {r['collective_s']:.4g} "
            f"| **{r['bound']}** | {args_gb:.2f} | {u} / {fr} |")
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | bound "
           "| state GB/dev | useful / frac |\n"
           "|---|---|---|---|---|---|---|---|")
    print(hdr)
    print("\n".join(rows))


if __name__ == "__main__":
    main()
