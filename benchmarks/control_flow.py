"""Predicated control-flow benchmark: compile + simulate the CONTROL_APPS.

The predication refactor (PR 10) claims branch/loop workloads ride the
same flow as the paper's straight-line apps with no special-casing.  This
bench holds that to numbers:

* **compile leg** — unpipelined vs fully-pipelined compiles of the three
  predicated apps (`thresh_conv`, `clip_pipe`, `refine`) next to the
  straight-line baselines (gaussian, unsharp, harris), reporting
  frequency, EDP, registers, and the pipelining speedup ratio;
* **sim leg** — 3-way backend bit-identity (interpreter / numpy / jax)
  on every predicated app, with per-backend wall times;
* **band checks** — the pipelined predicated apps must land in the
  straight-line frequency band (within slack) and gain the same order of
  EDP improvement from pipelining.

    PYTHONPATH=src python -m benchmarks.control_flow [--fast]
        [--bench-out BENCH_cf.json]

``benchmarks.run`` drives this as the ``cf`` section (``--only cf``) and
folds the rows into its trajectory record.
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks._util import (append_bench_record, apply_pnr_backend,
                              print_batch_stats, print_csv)
from repro.core.apps import ALL_APPS, CONTROL_APPS
from repro.core.compiler import CascadeCompiler, PassConfig

MOVES = 120
FAST_MOVES = 40
SIM_CYCLES = 1024
FAST_SIM_CYCLES = 256
BASELINES = ("gaussian", "unsharp", "harris")

#: Pipelined predicated apps may not fall below the straight-line
#: frequency band by more than this factor (they may exceed it freely).
FREQ_BAND_SLACK = 0.85
#: Pipelining must buy at least this EDP ratio on every predicated app —
#: the same order of improvement the paper's dense table shows.
MIN_EDP_RATIO = 1.5


def compile_rows(compiler: CascadeCompiler, moves: int = MOVES) -> List[Dict]:
    """Unpipelined vs full compiles: predicated apps + straight baselines."""
    apps = list(CONTROL_APPS) + list(BASELINES)
    configs = (PassConfig.unpipelined(place_moves=moves),
               PassConfig.full(place_moves=moves))
    pairs = [(a, cfg) for a in apps for cfg in configs]
    results = compiler.compile_batch([(ALL_APPS[a], cfg) for a, cfg in pairs])
    rows = []
    base: Dict[str, Dict] = {}
    for (app, cfg), r in zip(pairs, results):
        rec = {"freq_mhz": r.sta.max_freq_mhz, "edp": r.power.edp_js,
               "regs": r.design.physical_register_count()}
        if not cfg.compute_pipelining:
            base[app] = rec
        rows.append({"app": app,
                     "kind": "predicated" if app in CONTROL_APPS
                             else "straight",
                     "pipelined": int(cfg.compute_pipelining),
                     "freq_mhz": round(rec["freq_mhz"], 1),
                     "edp_ratio": round(base[app]["edp"] / rec["edp"], 2),
                     "registers": rec["regs"]})
    print_batch_stats(compiler, "control_flow")
    print_csv(rows, "control_flow_compile (unpipelined vs full)")
    return rows


def sim_rows(fast: bool = False) -> List[Dict]:
    """3-backend bit identity + wall time on every predicated app."""
    from repro.core import simulate

    cycles = FAST_SIM_CYCLES if fast else SIM_CYCLES
    rows = []
    for name, spec in sorted(CONTROL_APPS.items()):
        g = spec.build(1)
        rng = np.random.default_rng(0)
        ins = {n: rng.integers(0, 0x10000, size=cycles).tolist()
               for n, nd in g.nodes.items() if nd.kind == "input"}
        t0 = time.perf_counter()
        ref = simulate(g, ins, cycles)
        t_interp = time.perf_counter() - t0
        row = {"app": name, "cycles": cycles,
               "interp_s": round(t_interp, 4)}
        for backend in ("numpy", "jax"):
            t0 = time.perf_counter()
            out = simulate(g, ins, cycles, backend=backend)
            row[f"{backend}_s"] = round(time.perf_counter() - t0, 4)
            assert out == ref, f"{name}: {backend} diverged from interpreter"
        row["bit_identical"] = 1
        rows.append(row)
    print_csv(rows, "control_flow_sim (3-backend bit identity)")
    return rows


def band_checks(rows: List[Dict]) -> List[str]:
    """Assert the predicated apps land in the straight-line bands."""
    full = [r for r in rows if r["pipelined"]]
    straight = [r for r in full if r["kind"] == "straight"]
    pred = [r for r in full if r["kind"] == "predicated"]
    lo = min(r["freq_mhz"] for r in straight)
    hi = max(r["freq_mhz"] for r in straight)
    lines = []
    for r in pred:
        ok = r["freq_mhz"] >= lo * FREQ_BAND_SLACK
        assert ok, (f"{r['app']}: pipelined {r['freq_mhz']} MHz below the "
                    f"straight-line band [{lo}, {hi}]")
        lines.append(f"  {r['app']:12s} freq {r['freq_mhz']:7.1f} MHz   "
                     f"straight band [{lo:.1f}, {hi:.1f}]   OK")
        assert r["edp_ratio"] >= MIN_EDP_RATIO, \
            (f"{r['app']}: pipelining EDP ratio {r['edp_ratio']} < "
             f"{MIN_EDP_RATIO}x")
        lines.append(f"  {r['app']:12s} EDP gain {r['edp_ratio']:5.2f}x   "
                     f"(floor {MIN_EDP_RATIO}x)   OK")
    return lines


def run_all(fast: bool = False, backend: str = "auto",
            workers: Optional[int] = None,
            backend_pnr: Optional[str] = None,
            bench_out: Optional[str] = None) -> Dict[str, List[Dict]]:
    compiler = apply_pnr_backend(
        CascadeCompiler(batch_backend=backend, batch_workers=workers),
        backend_pnr)
    moves = FAST_MOVES if fast else MOVES
    rows = compile_rows(compiler, moves=moves)
    sims = sim_rows(fast=fast)
    print("\n== control-flow band check ==")
    for line in band_checks(rows):
        print(line)
    out = {"compile": rows, "sim": sims}
    if bench_out:
        append_bench_record(bench_out, {"fast": fast, **out})
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--bench-out", default="BENCH_cf.json")
    args = ap.parse_args()
    run_all(fast=args.fast, bench_out=args.bench_out)


if __name__ == "__main__":
    main()
