"""Docs lint: broken links, stale module references, architecture coverage.

    python tools/check_docs.py          # exit 1 on any failure

Three checks over ``docs/*.md`` + ``README.md`` (stdlib only, so the CI
docs job needs no dependencies):

1. **Intra-repo links** — every relative markdown link target
   (``[text](path)``) must exist on disk (anchors and external
   ``http(s)://`` / ``mailto:`` links are skipped).
2. **Stale module references** — every ``src/repro/...py`` path and every
   ``repro.core.<module>`` dotted name mentioned in prose/code spans must
   refer to a file that actually exists.
3. **Architecture coverage** — every module under ``src/repro/core/*.py``
   must be referenced in ``docs/architecture.md`` (new subsystems must be
   documented in the same PR that adds them).

Also importable (``tests/test_docs.py`` runs the same checks in tier-1).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import List

REPO = Path(__file__).resolve().parent.parent
ARCHITECTURE = REPO / "docs" / "architecture.md"

# [text](target) — target up to the first ')' or '#', skipping images' size
# attrs and reference-style links (which this repo doesn't use)
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)[^)]*\)")
_SRC_PATH_RE = re.compile(r"src/repro/[\w./-]+\.py")
_CORE_MOD_RE = re.compile(r"repro\.core\.(\w+)")
_EXTERNAL = ("http://", "https://", "mailto:")


def doc_files() -> List[Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def core_modules() -> List[str]:
    return sorted(p.stem for p in (REPO / "src/repro/core").glob("*.py"))


def check_links() -> List[str]:
    """Every relative markdown link must resolve (relative to its file)."""
    errors = []
    for md in doc_files():
        text = md.read_text()
        for m in _LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            resolved = (md.parent / target).resolve()
            if not resolved.exists():
                line = text[:m.start()].count("\n") + 1
                errors.append(f"{md.relative_to(REPO)}:{line}: broken link "
                              f"-> {target}")
    return errors


def check_stale_refs() -> List[str]:
    """Every src/repro path or repro.core dotted name must exist."""
    errors = []
    modules = set(core_modules())
    for md in doc_files():
        text = md.read_text()
        for m in _SRC_PATH_RE.finditer(text):
            if not (REPO / m.group(0)).exists():
                line = text[:m.start()].count("\n") + 1
                errors.append(f"{md.relative_to(REPO)}:{line}: stale path "
                              f"reference -> {m.group(0)}")
        for m in _CORE_MOD_RE.finditer(text):
            if m.group(1) not in modules:
                line = text[:m.start()].count("\n") + 1
                errors.append(f"{md.relative_to(REPO)}:{line}: stale module "
                              f"reference -> repro.core.{m.group(1)}")
    return errors


def check_architecture_coverage() -> List[str]:
    """docs/architecture.md must reference every repro.core module."""
    if not ARCHITECTURE.exists():
        return [f"missing {ARCHITECTURE}"]
    text = ARCHITECTURE.read_text()
    errors = []
    for mod in core_modules():
        if f"{mod}.py" not in text and f"repro.core.{mod}" not in text:
            errors.append(f"docs/architecture.md: core module {mod}.py is "
                          f"not documented")
    return errors


def main() -> int:
    errors = check_links() + check_stale_refs() + check_architecture_coverage()
    for e in errors:
        print(f"[check_docs] {e}", file=sys.stderr)
    files = len(doc_files())
    if errors:
        print(f"[check_docs] FAILED: {len(errors)} problem(s) across "
              f"{files} file(s)", file=sys.stderr)
        return 1
    print(f"[check_docs] OK: {files} doc file(s), "
          f"{len(core_modules())} core modules covered")
    return 0


if __name__ == "__main__":
    sys.exit(main())
