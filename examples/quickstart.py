"""Quickstart: the Cascade flow on one dense app, end to end.

    PYTHONPATH=src python examples/quickstart.py

Batch-compiles the unsharp-mask app unpipelined and fully pipelined in one
``compile_batch`` call, verifies the pipelined design is cycle-exact against
the source dataflow graph, prints the paper-style summary (frequency /
runtime / power / EDP) plus the per-pass wall-time breakdown, and
demonstrates the compile cache by re-compiling for free.
"""

from repro.core.apps import ALL_APPS
from repro.core.compiler import CascadeCompiler, PassConfig
from repro.core.sta import sdf_simulate_fmax


def main():
    compiler = CascadeCompiler()          # Amber-class 32x16 CGRA, GF12-cal
    app = ALL_APPS["unsharp"]

    print(f"== Cascade quickstart: {app.name} "
          f"({app.frame[0]}x{app.frame[1]} frame) ==")
    r0, r1 = compiler.compile_batch(
        [(app, PassConfig.unpipelined()), (app, PassConfig.full())],
        verify=True)
    print(f"unpipelined: {r0.summary()}")
    print(f"pipelined  : {r1.summary()}")
    assert r1.pass_stats["verified"], "functional equivalence check"

    cp = r0.sta.critical_path_ns / r1.sta.critical_path_ns
    edp = r0.power.edp_js / r1.power.edp_js
    print(f"critical path ratio: {cp:.1f}x   EDP ratio: {edp:.1f}x "
          f"(paper bands: 7-34x / 7-190x)")

    sdf = sdf_simulate_fmax(r1.design, compiler.timing)
    print(f"STA fmax {r1.sta.max_freq_mhz:.0f} MHz vs SDF-sim {sdf:.0f} MHz "
          f"(STA is the pessimistic bound)")
    print("pass pipeline:", " -> ".join(r1.pass_stats["pipeline"]))
    print("pass times (ms):",
          {k: round(v * 1e3, 1)
           for k, v in r1.pass_stats["pass_times"].items()})

    # the compile cache: same (app, config) again is a content-hash hit
    r2 = compiler.compile(app, PassConfig.full(), verify=True)
    assert r2.cache_hit and r2.summary() == r1.summary()
    print(f"re-compile: cache hit in {r2.compile_seconds * 1e3:.1f} ms "
          f"-> {compiler.cache.stats()}")


if __name__ == "__main__":
    main()
