"""End-to-end LM training driver: a ~100M-parameter llama-family model
trained for a few hundred steps on the deterministic synthetic pipeline,
with checkpointing, an injected mid-run failure, and automatic recovery.

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --tiny     # CI-speed smoke

The same launcher (repro.launch.train) runs the full assigned configs on
the production mesh; this example pins a container-sized config.
"""

import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import SyntheticLMData
from repro.distributed import sharding as shd
from repro.launch import steps as S
from repro.launch.mesh import make_smoke_mesh
from repro.models import LM, param_count
from repro.runtime import FailureInjector, FaultTolerantLoop, StragglerPolicy


def config_100m():
    """llama-family ~100M: 12L x 512d x 2048ff, 32k vocab."""
    return get_config("llama3-8b").replace(
        name="llama-100m", num_layers=12, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
        remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    if args.tiny:
        cfg = get_config("llama3-8b").smoke()
        steps, b, s = args.steps or 20, 4, 64
    else:
        cfg = config_100m()
        steps, b, s = args.steps or 300, 4, 256
    shape = ShapeSpec("example", s, b, "train")
    model = LM(cfg)
    print(f"[example] {cfg.name}: "
          f"{param_count(model.param_defs()) / 1e6:.1f}M params, "
          f"{steps} steps of {b}x{s} tokens")

    opt_cfg = S.make_optimizer_config(cfg, total_steps=steps)
    shd.set_rules(S.rules_for(cfg))
    mesh = make_smoke_mesh()
    data = SyntheticLMData(cfg, shape)
    ckpt_dir = tempfile.mkdtemp(prefix="train_lm_ckpt_")
    mgr = CheckpointManager(ckpt_dir, keep=2, async_save=True)

    with mesh:
        st_sh, b_sh = S.train_shardings(model, opt_cfg, mesh, shape)
        step_fn = jax.jit(S.make_train_step(model, opt_cfg),
                          in_shardings=(st_sh, b_sh),
                          out_shardings=(st_sh, NamedSharding(mesh, P())),
                          donate_argnums=(0,))
        state = S.init_train_state(model, opt_cfg, jax.random.PRNGKey(0))

        losses = []

        def wrapped(st, batch):
            st2, loss = step_fn(st, batch)
            losses.append(float(loss))
            return st2

        loop = FaultTolerantLoop(
            step_fn=wrapped,
            batch_fn=lambda i: data.batch(i),
            ckpt_save=lambda i, st: mgr.save(i, st),
            ckpt_restore=lambda: mgr.restore_latest(state),
            checkpoint_every=max(10, steps // 6),
            injector=FailureInjector(fail_at={steps // 2: "sim-preemption"}),
            straggler=StragglerPolicy(),
        )
        state, end, history = loop.run(state, 0, steps)

    k = max(1, len(losses) // 10)
    print(f"[example] loss {losses[0]:.4f} -> "
          f"{sum(losses[-k:]) / k:.4f} over {len(losses)} executed steps")
    print(f"[example] fault-tolerance events: {history}")
    if steps >= 20:       # too few steps to clear warmup otherwise
        assert sum(losses[-k:]) / k < losses[0], "training must reduce loss"
    mgr.wait()
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    print("[example] OK")


if __name__ == "__main__":
    main()
