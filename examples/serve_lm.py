"""Serve a small model with batched requests: prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6-7b]

Runs reduced-family configs of three architectures (dense GQA, attention-
free RWKV6, hybrid Mamba2) through the identical serving path the dry-run
lowers at 32k/500k scale, and reports prefill/decode throughput.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.launch import steps as S
from repro.launch.mesh import make_smoke_mesh
from repro.models import LM


def serve_one(arch: str, b=4, plen=32, gen=16):
    cfg = get_config(arch).smoke()
    model = LM(cfg)
    shd.set_rules(S.rules_for(cfg))
    mesh = make_smoke_mesh()
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(b, plen + gen)
        prefill = jax.jit(S.make_prefill_step(model))
        decode = jax.jit(S.make_decode_step(model), donate_argnums=(2,))
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (b, plen), 0, cfg.vocab_size)}
        if cfg.family == "vlm":
            batch["image_embeds"] = 0.1 * jnp.ones(
                (b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            batch["frames"] = 0.1 * jnp.ones((b, 1500, cfg.d_model),
                                             jnp.bfloat16)
        logits, cache = prefill(params, batch, cache)
        toks = jnp.argmax(logits, -1)[:, None]
        t0 = time.time()
        for i in range(gen - 1):
            logits, cache = decode(params, {"tokens": toks}, cache,
                                   jnp.int32(plen + i))
            toks = jnp.argmax(logits, -1)[:, None]
        jax.block_until_ready(toks)
        dt = time.time() - t0
    print(f"[serve_lm] {arch:12s} ({cfg.family:6s}): "
          f"{b * (gen - 1) / dt:7.1f} tok/s decode "
          f"(batch={b}, ctx={plen + gen})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else \
        ["llama3-8b", "rwkv6-7b", "zamba2-2.7b"]
    for a in archs:
        serve_one(a)
    print("[serve_lm] OK")


if __name__ == "__main__":
    main()
