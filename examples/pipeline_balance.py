"""Cascade's post-PnR loop as a pipeline-parallel stage balancer.

    PYTHONPATH=src python examples/pipeline_balance.py [--stages 4]

Shows the paper's idea — iteratively break the critical segment, then
re-balance — applied to heterogeneous LM layer stacks (zamba2's shared
attention blocks, llama4's dense/MoE interleave) at cluster scale.
"""

import argparse

from repro.configs import ARCHS, SHAPES
from repro.distributed.pipeline import layer_costs, plan_for


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=8)
    args = ap.parse_args()
    shape = SHAPES["train_4k"]

    for arch in ("zamba2-2.7b", "llama4-maverick-400b-a17b",
                 "mistral-large-123b"):
        cfg = ARCHS[arch]
        costs = layer_costs(cfg, shape, chips_per_stage=64,
                            microbatches=args.microbatches)
        plans = plan_for(cfg, shape, num_stages=args.stages,
                         chips_per_stage=64,
                         microbatches=args.microbatches)
        cas, nai = plans["cascade"], plans["naive"]
        print(f"\n== {arch} ({cfg.num_layers} layers, "
              f"{args.stages} stages x 64 chips) ==")
        print(f"  layer cost spread: {min(costs)*1e3:.2f} - "
              f"{max(costs)*1e3:.2f} ms/microbatch")
        print(f"  naive equal-count : beat {nai.beat_s*1e3:8.3f} ms  "
              f"bounds {nai.boundaries}")
        print(f"  cascade balanced  : beat {cas.beat_s*1e3:8.3f} ms  "
              f"bounds {cas.boundaries}")
        print(f"  beat speedup {nai.beat_s / cas.beat_s:.3f}x   "
              f"makespan speedup {nai.makespan_s / cas.makespan_s:.3f}x   "
              f"bubble {cas.bubble_frac:.2%}")
        if cas.history:
            trail = " -> ".join(f"{s}:{b*1e3:.1f}ms" for s, b in cas.history)
            print(f"  break-the-critical-segment trail: {trail}")


if __name__ == "__main__":
    main()
