"""Power-capped pipelining: the Capstone-style schedule, end to end.

    PYTHONPATH=src python examples/power_capped.py
    CASCADE_POWER_CAP_MW=300 PYTHONPATH=src python examples/power_capped.py

Compiles the Harris corner detector three ways — unconstrained, and under
two power caps — and prints the Pareto point each run reaches (frequency,
power, EDP, registers spent) plus the round-by-round trajectory of the
capped run, showing where the budget controller rolled back the round
that would have crossed the cap.

Set ``CASCADE_POWER_CAP_MW`` to try a cap of your own; it is written into
the ``PassConfig`` (never read inside the compiler), so compile-cache
entries key on it like any other config field.
"""

from repro.core import default_power_cap_mw
from repro.core.apps import ALL_APPS
from repro.core.compiler import CascadeCompiler, PassConfig


def main():
    compiler = CascadeCompiler()
    app = ALL_APPS["harris"]
    moves = 100

    print(f"== Power-capped pipelining: {app.name} ==")
    base = compiler.compile(app, PassConfig.power_capped(
        None, place_moves=moves))
    p0 = base.power.power_mw
    print(f"uncapped: {base.summary()}")
    print(f"  trajectory (mW): "
          f"{[round(pt.power_mw, 1) for pt in base.power_cap.trajectory]}")

    env_cap = default_power_cap_mw()
    caps = [env_cap] if env_cap is not None else [0.9 * p0, 0.75 * p0]
    for cap in caps:
        r = compiler.compile(app, PassConfig.power_capped(
            cap, place_moves=moves))
        pc = r.power_cap
        print(f"\ncap {cap:.1f} mW -> {pc.summary()}")
        print(f"  trajectory (mW): "
              f"{[round(pt.power_mw, 1) for pt in pc.trajectory]}")
        if pc.rounds_rolled_back:
            print(f"  controller rolled back the round that crossed the cap "
                  f"(checkpointed design state restored)")
        assert r.power.power_mw <= cap or not pc.feasible, \
            "reported power must respect the cap"
        slowdown = base.sta.max_freq_mhz / r.sta.max_freq_mhz
        saved = p0 - r.power.power_mw
        print(f"  vs uncapped: {saved:.1f} mW saved for {slowdown:.2f}x "
              f"lower clock, {pc.final.registers_added} vs "
              f"{base.power_cap.final.registers_added} registers spent")


if __name__ == "__main__":
    main()
