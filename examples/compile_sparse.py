"""Sparse (ready-valid) pipelining walkthrough: Tensor TTV through the
FIFO-insertion flow, with token-level simulation proving stream equivalence.

    PYTHONPATH=src python examples/compile_sparse.py
"""

import numpy as np

from repro.core.apps import ALL_APPS
from repro.core.compiler import CascadeCompiler, PassConfig
from repro.core.dfg import INPUT
from repro.core.sim import simulate_sparse


def main():
    compiler = CascadeCompiler()
    app = ALL_APPS["ttv"]
    print(f"== sparse pipelining: {app.name} ==")

    # compute-pipelining-only baseline (sparse apps carry input FIFOs by
    # construction, Section VIII-D) vs the full flow — one batch call
    base, full = compiler.compile_batch([
        (app, PassConfig(broadcast_pipelining=False, placement_alpha=1.0,
                         post_pnr=False, low_unroll_dup=False)),
        (app, PassConfig.full()),
    ])
    print(f"compute-only: {base.summary()}")
    print(f"full        : {full.summary()}")
    print(f"critical path ratio {base.sta.critical_path_ns / full.sta.critical_path_ns:.2f}x "
          f"(paper sparse band 2-4.4x vs unpipelined)")

    # token-level equivalence: FIFO insertion must not change the streams
    g_ref = app.build(1)
    rng = np.random.default_rng(0)
    ins = {n: rng.integers(0, 99, size=16).tolist()
           for n, nd in g_ref.nodes.items() if nd.kind == INPUT}
    out_ref = simulate_sparse(g_ref, ins)
    out_full = simulate_sparse(full.design.netlist.to_dfg(), ins)
    assert out_ref == out_full, "ready-valid streams must be preserved"
    print("token streams identical after FIFO pipelining: OK")


if __name__ == "__main__":
    main()
